//! Parameter serialization (the paper's `FL_SAVE_LOAD` facility): a small
//! self-describing binary format — magic, version, per-tensor dtype +
//! shape + raw little-endian data.

use std::io::{Read, Write};
use std::path::Path;

use crate::autograd::Variable;
use crate::tensor::{DType, HostBuffer, Shape, Tensor};
use crate::util::error::{Error, Result};

const MAGIC: &[u8; 8] = b"FLCKPT01";

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::F64 => 1,
        DType::I32 => 2,
        DType::I64 => 3,
        DType::U8 => 4,
        DType::Bool => 5,
    }
}

fn code_dtype(c: u8) -> Result<DType> {
    Ok(match c {
        0 => DType::F32,
        1 => DType::F64,
        2 => DType::I32,
        3 => DType::I64,
        4 => DType::U8,
        5 => DType::Bool,
        _ => return Err(Error::Serde(format!("bad dtype code {c}"))),
    })
}

fn write_tensor(w: &mut impl Write, t: &Tensor) -> Result<()> {
    w.write_all(&[dtype_code(t.dtype())])?;
    w.write_all(&(t.rank() as u32).to_le_bytes())?;
    for &d in t.dims() {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    match t.to_host() {
        HostBuffer::F32(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        HostBuffer::F64(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        HostBuffer::I32(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        HostBuffer::I64(v) => {
            for x in v {
                w.write_all(&x.to_le_bytes())?;
            }
        }
        HostBuffer::U8(v, _) => w.write_all(&v)?,
    }
    Ok(())
}

fn read_exact<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_tensor(r: &mut impl Read) -> Result<Tensor> {
    let dtype = code_dtype(read_exact::<1>(r)?[0])?;
    let rank = u32::from_le_bytes(read_exact::<4>(r)?) as usize;
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(u64::from_le_bytes(read_exact::<8>(r)?) as usize);
    }
    let shape = Shape::new(dims);
    let n = shape.numel();
    let host = match dtype {
        DType::F32 => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(f32::from_le_bytes(read_exact::<4>(r)?));
            }
            HostBuffer::F32(v)
        }
        DType::F64 => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(f64::from_le_bytes(read_exact::<8>(r)?));
            }
            HostBuffer::F64(v)
        }
        DType::I32 => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(i32::from_le_bytes(read_exact::<4>(r)?));
            }
            HostBuffer::I32(v)
        }
        DType::I64 => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                v.push(i64::from_le_bytes(read_exact::<8>(r)?));
            }
            HostBuffer::I64(v)
        }
        DType::U8 | DType::Bool => {
            let mut v = vec![0u8; n];
            r.read_exact(&mut v)?;
            HostBuffer::U8(v, dtype == DType::Bool)
        }
    };
    Ok(Tensor::from_host(host, shape))
}

/// Stream the full checkpoint (magic, count, tensors) to `w`.
fn write_params(w: &mut impl Write, params: &[Variable]) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(params.len() as u64).to_le_bytes())?;
    for p in params {
        write_tensor(w, &p.tensor())?;
    }
    Ok(())
}

/// The sibling scratch file a save streams into before the atomic rename.
fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".tmp");
    std::path::PathBuf::from(s)
}

/// Save parameter tensors in order.
///
/// The write is atomic with respect to crashes: the checkpoint streams
/// into `<path>.tmp` and only a fully-written, fsynced file is renamed
/// over `path` (rename within a filesystem replaces atomically). A
/// process killed mid-write leaves at worst a stale `.tmp` behind — the
/// previous checkpoint at `path` is never truncated or half-overwritten,
/// so a training run interrupted during its periodic save can always
/// resume from the last complete snapshot.
pub fn save_params(path: &Path, params: &[Variable]) -> Result<()> {
    let tmp = tmp_path(path);
    let write = (|| -> Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        write_params(&mut f, params)?;
        f.flush()?;
        // durability before the swap: the rename must not land before the
        // data it points at
        f.get_ref().sync_all()?;
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Load a checkpoint into parameters (order and shapes must match).
pub fn load_params(path: &Path, params: &[Variable]) -> Result<()> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let magic = read_exact::<8>(&mut f)?;
    if &magic != MAGIC {
        return Err(Error::Serde("bad checkpoint magic".into()));
    }
    let count = u64::from_le_bytes(read_exact::<8>(&mut f)?) as usize;
    if count != params.len() {
        return Err(Error::Serde(format!(
            "checkpoint has {count} tensors, model has {}",
            params.len()
        )));
    }
    for p in params {
        let t = read_tensor(&mut f)?;
        if t.shape() != &p.shape() {
            return Err(Error::Serde(format!(
                "shape mismatch: checkpoint {} vs model {}",
                t.shape(),
                p.shape()
            )));
        }
        p.set_tensor(t);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{Linear, Module};

    #[test]
    fn roundtrip_preserves_values() {
        let dir = std::env::temp_dir().join("fl_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let a = Linear::new(4, 3);
        save_params(&path, &a.params()).unwrap();
        let b = Linear::new(4, 3);
        assert_ne!(a.weight.tensor().to_vec(), b.weight.tensor().to_vec());
        load_params(&path, &b.params()).unwrap();
        assert_eq!(a.weight.tensor().to_vec(), b.weight.tensor().to_vec());
        assert_eq!(
            a.bias.as_ref().unwrap().tensor().to_vec(),
            b.bias.as_ref().unwrap().tensor().to_vec()
        );
    }

    #[test]
    fn shape_mismatch_rejected() {
        let dir = std::env::temp_dir().join("fl_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let a = Linear::new(4, 3);
        save_params(&path, &a.params()).unwrap();
        let b = Linear::new(5, 3);
        assert!(load_params(&path, &b.params()).is_err());
        let c = Linear::new_no_bias(4, 3);
        assert!(load_params(&path, &c.params()).is_err()); // count mismatch
    }

    /// A writer that fails once `budget` bytes have been accepted —
    /// simulates a disk-full / crash partway through a checkpoint stream.
    struct FailAfter {
        written: Vec<u8>,
        budget: usize,
    }

    impl Write for FailAfter {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            if self.written.len() + buf.len() > self.budget {
                return Err(std::io::Error::other("simulated mid-write failure"));
            }
            self.written.extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn mid_write_failure_never_corrupts_existing_checkpoint() {
        let dir = std::env::temp_dir().join("fl_ckpt_atomic");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.ckpt");
        let a = Linear::new(6, 5);
        save_params(&path, &a.params()).unwrap();
        let golden = std::fs::read(&path).unwrap();

        // the streaming format really does fail partway through a tensor
        let b = Linear::new(6, 5);
        let mut failing = FailAfter { written: Vec::new(), budget: 24 };
        assert!(write_params(&mut failing, &b.params()).is_err());
        assert!(!failing.written.is_empty(), "failure must be mid-stream, not at byte 0");

        // a crashed save leaves exactly those partial bytes in the scratch
        // file; the checkpoint itself must be untouched and loadable
        let tmp = tmp_path(&path);
        std::fs::write(&tmp, &failing.written).unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), golden, "target mutated before rename");
        let c = Linear::new(6, 5);
        load_params(&path, &c.params()).unwrap();
        assert_eq!(a.weight.tensor().to_vec(), c.weight.tensor().to_vec());

        // the next successful save consumes the scratch file and swaps in
        // the new snapshot whole
        save_params(&path, &b.params()).unwrap();
        assert!(!tmp.exists(), "scratch file must not outlive a successful save");
        let d = Linear::new(6, 5);
        load_params(&path, &d.params()).unwrap();
        assert_eq!(b.weight.tensor().to_vec(), d.weight.tensor().to_vec());
    }

    #[test]
    fn corrupt_magic_rejected() {
        let dir = std::env::temp_dir().join("fl_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        std::fs::write(&path, b"NOTACKPT____").unwrap();
        let a = Linear::new(2, 2);
        assert!(load_params(&path, &a.params()).is_err());
    }
}
