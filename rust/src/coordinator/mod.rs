//! Training coordinator: config system, trainer (train/eval loops with
//! meters and checkpoints), and a data-parallel launcher over the
//! distributed interface. This is the "application" layer of paper
//! Figure 1, generalized into a reusable runtime.

pub mod checkpoint;
pub mod config;
pub mod step;
pub mod trainer;

pub use checkpoint::{load_params, save_params};
pub use config::TrainConfig;
pub use step::{
    compile_step, compile_step_fn, BatchSpec, CompiledTrainStep, StepResult, TrainStepState,
};
pub use trainer::{train_classifier, train_data_parallel, train_lm, TrainReport};
