//! Training loops (paper Listings 9–10 generalized): classifier and LM
//! trainers with meters, gradient clipping, LR schedules, checkpoints, and
//! a data-parallel launcher that replicates the model across ring workers.
//!
//! The launcher ([`train_data_parallel`]) is the application-layer face of
//! the open [`DistributedInterface`]: it builds an in-process ring with
//! [`init_ring`], broadcasts rank 0's parameters so every replica starts
//! identical, and averages gradients after each backward pass through a
//! [`GradientSynchronizer`]. Because the ring all-reduce is bitwise
//! deterministic, replicas stay exactly synchronized — checked by
//! [`replica_divergence`] and the tests below.
//!
//! Single-process entry points:
//! - [`train_classifier`] — `(input, label)` batches, eval pass, meters.
//! - [`train_lm`] — autoregressive windows through [`BertLike`].

use std::sync::Arc;

use crate::autograd::{ops, Variable};
use crate::data::{BatchDataset, Dataset};
use crate::dist::{init_ring, DistributedInterface, GradientSynchronizer};
use crate::meter::{AverageValueMeter, FrameErrorMeter, TimeMeter};
use crate::models::BertLike;
use crate::nn::{categorical_cross_entropy, Module};
use crate::optim::{clip_grad_norm, AdamOptimizer, AdamWOptimizer, Optimizer, SGDOptimizer};
use crate::tensor::{default_backend, Tensor};
use crate::util::error::{Error, Result};

use super::config::TrainConfig;
pub use super::step::{
    compile_step, compile_step_fn, BatchSpec, CompiledTrainStep, StepResult, TrainStepState,
};

/// Summary of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// (step, loss) curve at `log_every` resolution.
    pub loss_curve: Vec<(usize, f64)>,
    /// Final train loss.
    pub final_loss: f64,
    /// Samples per second.
    pub throughput: f64,
    /// Final eval error (%, classifiers only).
    pub eval_error: Option<f64>,
}

/// Build the configured optimizer. Unknown optimizer strings are an
/// error (they used to fall back to Adam silently); the accepted set
/// mirrors [`crate::optim::UpdateRule::from_config`] so eager and
/// compiled steps agree on the arithmetic.
pub fn make_optimizer(cfg: &TrainConfig, params: Vec<Variable>) -> Result<Box<dyn Optimizer>> {
    match cfg.optimizer.as_str() {
        "sgd" => Ok(Box::new(SGDOptimizer::with_momentum(params, cfg.lr, 0.9, false))),
        "adam" => Ok(Box::new(AdamOptimizer::new(params, cfg.lr))),
        "adamw" => Ok(Box::new(AdamWOptimizer::new(params, cfg.lr, 0.01))),
        other => Err(Error::Config(format!(
            "unknown optimizer `{other}` (expected sgd | adam | adamw)"
        ))),
    }
}

/// Number of leading batches that share the traced (full) batch shape.
/// Compiled steps specialize shapes at trace time, so the compiled paths
/// cycle over these and skip a ragged tail batch (the eager paths train
/// on it; make the dataset length divisible by the batch size for exact
/// data parity between the two).
fn full_batches(batches: &BatchDataset) -> usize {
    let n = batches.len();
    if n > 1 && batches.get(n - 1)[0].dim(0) != batches.get(0)[0].dim(0) {
        n - 1
    } else {
        n
    }
}

/// Per-worker RNG stream for data-parallel training: deterministic in
/// `(seed, rank)` so eager and compiled replicas draw identical dropout
/// masks (the compiled branch re-aligns to this after tracing).
fn worker_stream(seed: u64, rank: usize) -> u64 {
    seed ^ (rank as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Train a classifier on `(input, label)` batches (paper Listing 9).
pub fn train_classifier(
    model: &mut dyn Module,
    dataset: Arc<dyn Dataset>,
    cfg: &TrainConfig,
    mut log: impl FnMut(usize, f64),
) -> Result<TrainReport> {
    crate::util::rng::seed(cfg.seed);
    model.set_train(true);
    let batches = BatchDataset::new(dataset.clone(), cfg.batch_size);
    let mut loss_meter = AverageValueMeter::new();
    let mut curve = Vec::new();
    let mut timer = TimeMeter::start();

    if cfg.compile_step {
        // one traced program per step: forward + backward + clip + update
        let spec = BatchSpec::like(&batches.get(0));
        let step = compile_step(&*model, cfg, &spec)?;
        // tracing ran one forward (consuming RNG draws); realign the
        // stream so the compiled run replays the eager run's draws
        crate::util::rng::seed(cfg.seed);
        let be = default_backend();
        let n_full = full_batches(&batches);
        let mut params: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
        let mut state = step.init_state(&params);
        for s in 0..cfg.steps {
            let batch = batches.get(s % n_full);
            let items = batch[0].dim(0) as u64;
            let out = step.run(be.as_ref(), params, state, &batch, true)?;
            params = out.params;
            state = out.state;
            loss_meter.add(out.loss);
            timer.add_items(items);
            if (s + 1) % cfg.log_every == 0 || s + 1 == cfg.steps {
                log(s + 1, loss_meter.value());
                curve.push((s + 1, loss_meter.value()));
                loss_meter.reset();
            }
        }
        for (p, t) in model.params().iter().zip(&params) {
            p.set_tensor(t.clone());
        }
    } else {
        let mut opt = make_optimizer(cfg, model.params())?;
        for step in 0..cfg.steps {
            let batch = batches.get(step % batches.len());
            let inputs = Variable::constant(batch[0].clone());
            let targets = batch[1].clone();
            let output = model.forward(&inputs);
            let loss = categorical_cross_entropy(&output, &targets);
            let lv = loss.tensor().item();
            loss_meter.add(lv);
            loss.backward();
            if cfg.grad_clip > 0.0 {
                clip_grad_norm(opt.params(), cfg.grad_clip);
            }
            opt.step();
            opt.zero_grad();
            timer.add_items(batch[0].dim(0) as u64);
            if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
                log(step + 1, loss_meter.value());
                curve.push((step + 1, loss_meter.value()));
                loss_meter.reset();
            }
        }
    }

    // eval pass over the dataset
    model.set_train(false);
    let mut err = FrameErrorMeter::new();
    crate::autograd::no_grad(|| {
        for i in 0..batches.len().min(cfg.eval_batches) {
            let batch = batches.get(i);
            let out = model.forward(&Variable::constant(batch[0].clone()));
            let pred = out.tensor().argmax(-1, false);
            err.add(&pred, &batch[1]);
        }
    });
    model.set_train(true);

    if !cfg.checkpoint.is_empty() {
        super::checkpoint::save_params(std::path::Path::new(&cfg.checkpoint), &model.params())?;
    }
    Ok(TrainReport {
        final_loss: curve.last().map(|&(_, l)| l).unwrap_or(f64::NAN),
        loss_curve: curve,
        throughput: timer.items_per_sec(),
        eval_error: Some(err.value()),
    })
}

/// Train a [`BertLike`] language model on `[1, L+1]` token windows.
pub fn train_lm(
    model: &BertLike,
    dataset: Arc<dyn Dataset>,
    cfg: &TrainConfig,
    mut log: impl FnMut(usize, f64),
) -> Result<TrainReport> {
    crate::util::rng::seed(cfg.seed);
    let batches = BatchDataset::new(dataset, cfg.batch_size);
    let mut loss_meter = AverageValueMeter::new();
    let mut curve = Vec::new();
    let mut timer = TimeMeter::start();
    if cfg.compile_step {
        let example = batches.get(0);
        let step = compile_step_fn(&model.params(), cfg, &example[..1], |batch| {
            crate::models::bert::lm_loss(model, &batch[0])
        })?;
        crate::util::rng::seed(cfg.seed);
        let be = default_backend();
        let n_full = full_batches(&batches);
        let mut params: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
        let mut state = step.init_state(&params);
        for s in 0..cfg.steps {
            let batch = batches.get(s % n_full);
            let out = step.run(be.as_ref(), params, state, &batch[..1], true)?;
            params = out.params;
            state = out.state;
            loss_meter.add(out.loss);
            timer.add_items(batch[0].dim(0) as u64);
            if (s + 1) % cfg.log_every == 0 || s + 1 == cfg.steps {
                log(s + 1, loss_meter.value());
                curve.push((s + 1, loss_meter.value()));
                loss_meter.reset();
            }
        }
        for (p, t) in model.params().iter().zip(&params) {
            p.set_tensor(t.clone());
        }
    } else {
        let mut opt = make_optimizer(cfg, model.params())?;
        for step in 0..cfg.steps {
            let batch = batches.get(step % batches.len());
            let loss = crate::models::bert::lm_loss(model, &batch[0]);
            let lv = loss.tensor().item();
            loss_meter.add(lv);
            loss.backward();
            if cfg.grad_clip > 0.0 {
                clip_grad_norm(opt.params(), cfg.grad_clip);
            }
            opt.step();
            opt.zero_grad();
            timer.add_items(batch[0].dim(0) as u64);
            if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
                log(step + 1, loss_meter.value());
                curve.push((step + 1, loss_meter.value()));
                loss_meter.reset();
            }
        }
    }
    if !cfg.checkpoint.is_empty() {
        super::checkpoint::save_params(std::path::Path::new(&cfg.checkpoint), &model.params())?;
    }
    Ok(TrainReport {
        final_loss: curve.last().map(|&(_, l)| l).unwrap_or(f64::NAN),
        loss_curve: curve,
        throughput: timer.items_per_sec(),
        eval_error: None,
    })
}

/// Data-parallel launcher: spawns `cfg.workers` threads, each with its own
/// model replica built by `make_model`; parameters are broadcast from rank
/// 0 and gradients averaged through the ring after every step (the
/// topology the paper's Table 3 "8 GPUs" column exercises).
pub fn train_data_parallel(
    make_model: impl Fn() -> Box<dyn Module> + Send + Sync,
    make_data: impl Fn(usize) -> Arc<dyn Dataset> + Send + Sync,
    cfg: &TrainConfig,
) -> Result<Vec<TrainReport>> {
    let workers = init_ring(cfg.workers);
    let cfg = cfg.clone();
    let results: Vec<Result<TrainReport>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for w in workers {
            let make_model = &make_model;
            let make_data = &make_data;
            let cfg = cfg.clone();
            handles.push(s.spawn(move || -> Result<TrainReport> {
                let rank = w.world_rank();
                let mut model = make_model();
                let dist: Arc<dyn DistributedInterface + Sync> = Arc::new(w);
                // parameter broadcast: replicas start identical
                for p in model.params() {
                    p.set_tensor(dist.broadcast(&p.tensor(), 0));
                }
                let sync = GradientSynchronizer::new(dist.clone());
                let data = make_data(rank);
                let batches = BatchDataset::new(data, cfg.batch_size);
                let mut curve = Vec::new();
                let mut meter = AverageValueMeter::new();
                let mut timer = TimeMeter::start();
                model.set_train(true);
                if cfg.compile_step {
                    // per-replica compiled step, split at the gradient
                    // boundary: traced backward -> bucketed all-reduce ->
                    // traced update (mirrors the eager loop, which does
                    // not clip in the data-parallel path)
                    let example = batches.get(0);
                    // tracing swaps the process-global default backend, so
                    // replicas must compile one at a time with no other
                    // tensor work in flight: quiesce at a barrier, compile
                    // (serialized by the trace lock), quiesce again before
                    // any post-compile tensor work starts. A compile error
                    // is config-shaped and hits every replica identically,
                    // so no replica is left waiting at the second barrier.
                    dist.barrier();
                    let step = compile_step_fn(&model.params(), &cfg, &example, |batch| {
                        let out = model.forward(&Variable::constant(batch[0].clone()));
                        if out.dims().len() == 3 {
                            // sequence logits: mean log-softmax proxy loss
                            ops::mean(&ops::mul(&out, &out), &[], false)
                        } else {
                            categorical_cross_entropy(&out, &batch[1])
                        }
                    })?;
                    dist.barrier();
                    let be = default_backend();
                    let n_full = full_batches(&batches);
                    let mut params: Vec<Tensor> =
                        model.params().iter().map(|p| p.tensor()).collect();
                    let mut state = step.init_state(&params);
                    // tracing consumed this worker's RNG draws; realign to
                    // the same per-rank stream the eager branch uses
                    crate::util::rng::reseed_thread(worker_stream(cfg.seed, rank));
                    for s in 0..cfg.steps {
                        let batch = batches.get(s % n_full);
                        let (grads, loss) = step.run_backward(be.as_ref(), &params, &batch)?;
                        let grads = sync.average_tensors(&grads);
                        let (p2, st2, _) =
                            step.run_update(be.as_ref(), params, grads, state, true)?;
                        params = p2;
                        state = st2;
                        meter.add(loss);
                        timer.add_items(batch[0].dim(0) as u64);
                        if (s + 1) % cfg.log_every == 0 || s + 1 == cfg.steps {
                            curve.push((s + 1, meter.value()));
                            meter.reset();
                        }
                    }
                    for (p, t) in model.params().iter().zip(&params) {
                        p.set_tensor(t.clone());
                    }
                } else {
                    let mut opt = make_optimizer(&cfg, model.params())?;
                    // deterministic per-rank stream (dropout masks), shared
                    // with the compiled branch for bit-parity
                    crate::util::rng::reseed_thread(worker_stream(cfg.seed, rank));
                    for step in 0..cfg.steps {
                        let batch = batches.get(step % batches.len());
                        let out = model.forward(&Variable::constant(batch[0].clone()));
                        let loss = if out.dims().len() == 3 {
                            // sequence logits: mean log-softmax proxy loss
                            ops::mean(&ops::mul(&out, &out), &[], false)
                        } else {
                            categorical_cross_entropy(&out, &batch[1])
                        };
                        meter.add(loss.tensor().item());
                        loss.backward();
                        sync.synchronize(&opt.params().to_vec());
                        opt.step();
                        opt.zero_grad();
                        timer.add_items(batch[0].dim(0) as u64);
                        if (step + 1) % cfg.log_every == 0 || step + 1 == cfg.steps {
                            curve.push((step + 1, meter.value()));
                            meter.reset();
                        }
                    }
                }
                Ok(TrainReport {
                    final_loss: curve.last().map(|&(_, l)| l).unwrap_or(f64::NAN),
                    loss_curve: curve,
                    throughput: timer.items_per_sec(),
                    eval_error: None,
                })
            }));
        }
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    results.into_iter().collect()
}

/// Convenience for tests/examples: replicas end a data-parallel run with
/// bitwise-identical parameters; returns the max divergence.
pub fn replica_divergence(paramsets: &[Vec<Tensor>]) -> f64 {
    let mut worst = 0.0f64;
    for set in &paramsets[1..] {
        for (a, b) in paramsets[0].iter().zip(set) {
            worst = worst.max(a.max_abs_diff(b).unwrap());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::mlp;
    use crate::pkg::vision::synthetic_image_classification;

    #[test]
    fn classifier_trains_on_separable_blobs() {
        let ds = synthetic_image_classification(64, 1, 8, 2, 3);
        // flatten image samples for the MLP via a transform
        let flat = crate::data::TransformDataset::new(ds, |mut s| {
            let n = s[0].numel();
            s[0] = s[0].reshape(&[1, n as isize]);
            s
        });
        let mut model = mlp(&[64, 32, 2]);
        let cfg = TrainConfig { steps: 60, batch_size: 16, lr: 3e-3, ..Default::default() };
        let report =
            train_classifier(&mut model, Arc::new(flat), &cfg, |_, _| {}).unwrap();
        assert!(report.final_loss < 0.3, "loss {:.3}", report.final_loss);
        assert!(report.eval_error.unwrap() < 15.0, "err {:?}", report.eval_error);
        assert!(report.throughput > 0.0);
    }

    #[test]
    fn data_parallel_replicas_stay_in_sync() {
        let cfg = TrainConfig {
            steps: 6,
            batch_size: 4,
            workers: 3,
            lr: 1e-2,
            optimizer: "sgd".into(),
            log_every: 2,
            ..Default::default()
        };
        let reports = train_data_parallel(
            || Box::new(mlp(&[16, 8, 4])),
            |rank| {
                crate::data::TransformDataset::new(
                    synthetic_image_classification(16, 1, 4, 4, 100 + rank as u64),
                    |mut s| {
                        let n = s[0].numel();
                        s[0] = s[0].reshape(&[1, n as isize]);
                        s
                    },
                )
                .into()
            },
            &cfg,
        )
        .unwrap();
        assert_eq!(reports.len(), 3);
        // all workers completed the same number of logged intervals
        let lens: Vec<usize> = reports.iter().map(|r| r.loss_curve.len()).collect();
        assert!(lens.iter().all(|&l| l == lens[0]));
    }
}

impl From<crate::data::TransformDataset> for Arc<dyn Dataset> {
    fn from(d: crate::data::TransformDataset) -> Self {
        Arc::new(d)
    }
}
