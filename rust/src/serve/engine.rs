//! The serving engine: compiled session + dynamic batcher + continuous
//! decode scheduler + telemetry + graceful shutdown, behind one handle.

use std::sync::Arc;
use std::time::Duration;

use crate::models::BertLike;
use crate::tensor::{DType, Tensor};
use crate::util::error::{Error, Result};

use super::batcher::{Batcher, BatcherConfig, BatcherStats, ResponseHandle};
use super::generate::{GenerateOptions, GenerateReport};
use super::scheduler::{ContinuousBatcher, ContinuousConfig, ContinuousStats, GenHandle};
use super::session::InferenceSession;

/// Engine deployment knobs: the dynamic-batching policy for scoring
/// traffic plus the continuous-batching policy for decode traffic.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Largest dynamic batch (clamped to the session's largest bucket).
    pub max_batch_size: usize,
    /// How long the first request of a batch waits for companions.
    pub max_wait: Duration,
    /// Worker threads.
    pub workers: usize,
    /// Continuous-decode policy (slots, KV page size, pool capacity,
    /// decode buckets, prefill chunking); only used by
    /// [`Engine::start_lm`] engines.
    pub decode: ContinuousConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let b = BatcherConfig::default();
        EngineConfig {
            max_batch_size: b.max_batch_size,
            max_wait: b.max_wait,
            workers: b.workers,
            decode: ContinuousConfig::default(),
        }
    }
}

/// A point-in-time snapshot of everything the engine measures.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Batcher counters and latency percentiles (scoring traffic).
    pub batcher: BatcherStats,
    /// Continuous-scheduler counters: goodput, per-request latency
    /// percentiles, occupancy, pool accounting (decode traffic).
    /// `None` when the engine was deployed without an LM decoder
    /// ([`Engine::start`]) — distinct from a decoder that simply saw
    /// zero traffic, which reports `Some` of an all-zero snapshot.
    pub decode: Option<ContinuousStats>,
    /// Tokens produced by [`Engine::generate`] calls.
    pub generated_tokens: u64,
    /// Decode goodput (generated tokens per scheduler-busy second).
    pub decode_tokens_per_sec: f64,
}

/// One deployed model: score requests flow through the dynamic batcher
/// into shape-bucketed compiled programs; generation requests flow
/// through the continuous (iteration-level) scheduler over the paged KV
/// pool. Shutdown (explicit or on drop) drains both queues and joins
/// every thread.
pub struct Engine {
    batcher: Batcher,
    decoder: Option<ContinuousBatcher>,
}

impl Engine {
    /// Serve an already-compiled session.
    pub fn start(session: InferenceSession, cfg: &EngineConfig) -> Engine {
        let bcfg = BatcherConfig {
            max_batch_size: cfg.max_batch_size,
            max_wait: cfg.max_wait,
            workers: cfg.workers,
        };
        Engine { batcher: Batcher::start(Arc::new(session), bcfg), decoder: None }
    }

    /// Deploy a transformer LM: compiles `model.logits` over `[b, seq_len]`
    /// token windows for every batch bucket (scoring traffic), and starts
    /// the continuous scheduler for [`Engine::generate`] /
    /// [`Engine::submit_generate`] requests. Starting the scheduler also
    /// pre-compiles the decode-iteration buckets
    /// ([`super::CompiledDecodeStep`]) — engine startup is the warmup, so
    /// the first generation request never pays a trace+compile.
    pub fn start_lm(
        model: Arc<BertLike>,
        seq_len: usize,
        batch_buckets: &[usize],
        cfg: &EngineConfig,
    ) -> Result<Engine> {
        if seq_len == 0 || seq_len > model.max_len() {
            return Err(Error::msg(format!(
                "serve: seq_len {seq_len} outside the model's 1..={} window",
                model.max_len()
            )));
        }
        let traced = Arc::clone(&model);
        let session = InferenceSession::compile(&[seq_len], DType::I64, batch_buckets, move |ids| {
            traced.logits(ids).tensor()
        })?;
        let mut engine = Engine::start(session, cfg);
        engine.decoder = Some(ContinuousBatcher::start(model, &cfg.decode)?);
        Ok(engine)
    }

    /// Enqueue one example; returns a handle to block on.
    pub fn submit(&self, input: Tensor) -> ResponseHandle {
        self.batcher.submit(input)
    }

    /// Serve one example synchronously through the dynamic batcher.
    pub fn infer(&self, input: Tensor) -> Result<Tensor> {
        self.batcher.infer(input)
    }

    fn decoder(&self) -> Result<&ContinuousBatcher> {
        self.decoder
            .as_ref()
            .ok_or_else(|| Error::msg("serve: this engine was not deployed with an LM"))
    }

    /// Enqueue a generation request on the continuous scheduler (only
    /// available for [`Engine::start_lm`] engines); it joins the decode
    /// batch as soon as a slot and KV pages are free, regardless of who
    /// else is mid-generation.
    pub fn submit_generate(&self, prompt: &[i64], opts: &GenerateOptions) -> Result<GenHandle> {
        Ok(self.decoder()?.submit(prompt, opts))
    }

    /// Generate synchronously through the continuous scheduler. The
    /// report (and every report) is bit-identical to a solo
    /// [`super::generate()`] call with the same prompt and options,
    /// whatever else the engine is serving concurrently.
    pub fn generate(&self, prompt: &[i64], opts: &GenerateOptions) -> Result<GenerateReport> {
        self.decoder()?.generate(prompt, opts)
    }

    /// Telemetry snapshot. [`EngineStats::decode`] is `None` iff no
    /// decoder is configured — never conflated with an idle decoder's
    /// zero counters.
    pub fn stats(&self) -> EngineStats {
        let decode = self.decoder.as_ref().map(|d| d.stats());
        EngineStats {
            batcher: self.batcher.stats(),
            generated_tokens: decode.as_ref().map_or(0, |d| d.generated_tokens),
            decode_tokens_per_sec: decode.as_ref().map_or(0.0, |d| d.goodput_tps),
            decode,
        }
    }

    /// Graceful shutdown: serve everything already queued on both the
    /// scoring and decode paths, then join every thread. Safe to race
    /// with concurrent submits (they fail cleanly); dropping the engine
    /// does the same.
    pub fn shutdown(&self) {
        if let Some(d) = &self.decoder {
            d.shutdown();
        }
        self.batcher.shutdown();
    }
}
