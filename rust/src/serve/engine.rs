//! The serving engine: compiled session + dynamic batcher + telemetry +
//! graceful shutdown, behind one handle.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::meter::AverageValueMeter;
use crate::models::BertLike;
use crate::tensor::{DType, Tensor};
use crate::util::error::{Error, Result};

use super::batcher::{Batcher, BatcherConfig, BatcherStats, ResponseHandle};
use super::generate::{generate, GenerateOptions, GenerateReport};
use super::session::InferenceSession;

/// Engine deployment knobs (a thin rename of [`BatcherConfig`], kept
/// separate so serving policy can grow without touching the batcher).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Largest dynamic batch (clamped to the session's largest bucket).
    pub max_batch_size: usize,
    /// How long the first request of a batch waits for companions.
    pub max_wait: Duration,
    /// Worker threads.
    pub workers: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        let b = BatcherConfig::default();
        EngineConfig { max_batch_size: b.max_batch_size, max_wait: b.max_wait, workers: b.workers }
    }
}

/// A point-in-time snapshot of everything the engine measures.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Batcher counters and latency percentiles.
    pub batcher: BatcherStats,
    /// Tokens produced by [`Engine::generate`] calls.
    pub generated_tokens: u64,
    /// Mean decode throughput over [`Engine::generate`] calls, tokens/s.
    pub decode_tokens_per_sec: f64,
}

/// One deployed model: score requests flow through the dynamic batcher
/// into shape-bucketed compiled programs; generation requests run the
/// KV-cached decoder. Shutdown (explicit or on drop) drains the queue and
/// joins the workers.
pub struct Engine {
    batcher: Batcher,
    lm: Option<Arc<BertLike>>,
    generated_tokens: AtomicU64,
    decode_tps: Mutex<AverageValueMeter>,
}

impl Engine {
    /// Serve an already-compiled session.
    pub fn start(session: InferenceSession, cfg: &EngineConfig) -> Engine {
        let bcfg = BatcherConfig {
            max_batch_size: cfg.max_batch_size,
            max_wait: cfg.max_wait,
            workers: cfg.workers,
        };
        Engine {
            batcher: Batcher::start(Arc::new(session), bcfg),
            lm: None,
            generated_tokens: AtomicU64::new(0),
            decode_tps: Mutex::new(AverageValueMeter::new()),
        }
    }

    /// Deploy a transformer LM: compiles `model.logits` over `[b, seq_len]`
    /// token windows for every batch bucket (scoring traffic), and keeps
    /// the model for KV-cached [`Engine::generate`] requests.
    pub fn start_lm(
        model: Arc<BertLike>,
        seq_len: usize,
        batch_buckets: &[usize],
        cfg: &EngineConfig,
    ) -> Result<Engine> {
        if seq_len == 0 || seq_len > model.max_len() {
            return Err(Error::msg(format!(
                "serve: seq_len {seq_len} outside the model's 1..={} window",
                model.max_len()
            )));
        }
        let traced = Arc::clone(&model);
        let session = InferenceSession::compile(&[seq_len], DType::I64, batch_buckets, move |ids| {
            traced.logits(ids).tensor()
        })?;
        let mut engine = Engine::start(session, cfg);
        engine.lm = Some(model);
        Ok(engine)
    }

    /// Enqueue one example; returns a handle to block on.
    pub fn submit(&self, input: Tensor) -> ResponseHandle {
        self.batcher.submit(input)
    }

    /// Serve one example synchronously through the dynamic batcher.
    pub fn infer(&self, input: Tensor) -> Result<Tensor> {
        self.batcher.infer(input)
    }

    /// KV-cached autoregressive generation on the deployed LM (only
    /// available for [`Engine::start_lm`] engines). Decode telemetry
    /// feeds [`Engine::stats`].
    pub fn generate(&self, prompt: &[i64], opts: &GenerateOptions) -> Result<GenerateReport> {
        let model = self
            .lm
            .as_ref()
            .ok_or_else(|| Error::msg("serve: this engine was not deployed with an LM"))?;
        let report = generate(model, prompt, opts)?;
        self.generated_tokens.fetch_add(report.generated as u64, Ordering::Relaxed);
        if report.tokens_per_sec > 0.0 {
            self.decode_tps
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .add(report.tokens_per_sec);
        }
        Ok(report)
    }

    /// Telemetry snapshot.
    pub fn stats(&self) -> EngineStats {
        EngineStats {
            batcher: self.batcher.stats(),
            generated_tokens: self.generated_tokens.load(Ordering::Relaxed),
            decode_tokens_per_sec: self
                .decode_tps
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .value(),
        }
    }

    /// Graceful shutdown: serve everything already queued, then join the
    /// workers. Dropping the engine does the same.
    pub fn shutdown(mut self) {
        self.batcher.shutdown();
    }
}
