//! Shape-bucketed compiled inference programs.
//!
//! Serving traffic arrives at unpredictable batch sizes, but the graph
//! compiler specializes shapes at trace time. The classic resolution is
//! *shape bucketing*: compile the forward pass once per allowed batch
//! size, route each request batch to the smallest bucket that fits, and
//! pad the gap. Padding is sound here because every layer this engine
//! serves is row-independent — a padded row changes no other row's bits
//! (the batch-parity test in `rust/tests/serve.rs` enforces exactly
//! this).

use std::sync::Arc;

use crate::autograd::no_grad;
use crate::tensor::graph::{trace_and_compile, CompiledFn};
use crate::tensor::{default_backend, DType, Tensor, TensorBackend};
use crate::util::error::{Error, Result};

/// Snapshot the process-default backend while no trace capture is in
/// flight: a concurrent `trace_and_compile`/`compile_step` on another
/// thread has a `TraceBackend` installed as the process-global default,
/// and pinning *that* as a session's serving backend would corrupt the
/// other thread's capture on every later request. Taking (and releasing)
/// the trace lock around the read rules that out. Shared by
/// [`InferenceSession::compile`] and
/// [`crate::serve::CompiledDecodeStep::compile`].
pub(crate) fn quiesced_default_backend() -> Arc<dyn TensorBackend> {
    let _quiesced = crate::tensor::graph::trace_lock();
    default_backend()
}

/// A model forward compiled for a fixed set of batch-size buckets.
///
/// Construction traces the forward once per bucket (in inference mode:
/// run it under [`no_grad`], with dropout and other train-time behavior
/// off) and keeps the compiled programs for the session's lifetime —
/// the steady state serves every request with zero re-tracing.
pub struct InferenceSession {
    /// `(batch_size, program)` sorted ascending by batch size.
    buckets: Vec<(usize, CompiledFn)>,
    example_dims: Vec<usize>,
    out_rest: Vec<usize>,
    dtype: DType,
    backend: Arc<dyn TensorBackend>,
}

impl InferenceSession {
    /// Trace and compile `forward` for every batch size in
    /// `batch_buckets` over per-example inputs of shape `example_dims`
    /// and dtype `dtype` (so bucket `b` is traced at `[b, example_dims…]`).
    ///
    /// `forward` must be batch-major: output dimension 0 must equal the
    /// input batch size (validated here by probing each compiled
    /// program). Tracing installs the capture backend process-globally —
    /// compile on a quiescent process, before serving threads start.
    pub fn compile(
        example_dims: &[usize],
        dtype: DType,
        batch_buckets: &[usize],
        forward: impl Fn(&Tensor) -> Tensor,
    ) -> Result<InferenceSession> {
        let mut sizes: Vec<usize> = batch_buckets.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.is_empty() || sizes[0] == 0 {
            return Err(Error::msg("serve: batch buckets must be non-empty and positive"));
        }
        // the lock inside is taken and released before the bucket loop;
        // each bucket compile below re-acquires it for its own capture
        let backend = quiesced_default_backend();
        let mut buckets = Vec::with_capacity(sizes.len());
        let mut out_rest: Option<Vec<usize>> = None;
        for &b in &sizes {
            let mut bucket_span = crate::obs::span("serve.session.compile_bucket");
            bucket_span.attr_i64("batch", b as i64);
            let mut dims = vec![b];
            dims.extend_from_slice(example_dims);
            let example = Tensor::full(dims, 0.0, dtype);
            let compiled = no_grad(|| trace_and_compile(&[example], |args| forward(&args[0])))
                .map_err(|e| Error::msg(format!("serve: compiling batch bucket {b}: {e}")))?;
            // probe once: the traced examples are still the program's
            // defaults, so a direct run validates the batch-major contract
            let probe = compiled.program().run(backend.as_ref())?;
            let odims = probe[0].dims();
            if odims.first() != Some(&b) {
                return Err(Error::msg(format!(
                    "serve: forward is not batch-major — input batch {b} produced output \
                     shape {}",
                    probe[0].shape()
                )));
            }
            let rest = odims[1..].to_vec();
            match &out_rest {
                None => out_rest = Some(rest),
                Some(r) if *r == rest => {}
                Some(r) => {
                    return Err(Error::msg(format!(
                        "serve: per-example output shape differs across buckets \
                         ({r:?} vs {rest:?})"
                    )));
                }
            }
            buckets.push((b, compiled));
        }
        Ok(InferenceSession {
            buckets,
            example_dims: example_dims.to_vec(),
            out_rest: out_rest.unwrap_or_default(),
            dtype,
            backend,
        })
    }

    /// Serve on a specific backend instead of the default one captured at
    /// construction (worker threads always use this handle, so a backend
    /// swap elsewhere in the process cannot redirect in-flight serving).
    pub fn with_backend(mut self, backend: Arc<dyn TensorBackend>) -> Self {
        self.backend = backend;
        self
    }

    /// The compiled batch sizes, ascending.
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.buckets.iter().map(|(b, _)| *b).collect()
    }

    /// Largest batch one program call can serve.
    pub fn max_batch(&self) -> usize {
        self.buckets.last().map_or(0, |(b, _)| *b)
    }

    /// Per-example input dims (without the batch axis).
    pub fn example_dims(&self) -> &[usize] {
        &self.example_dims
    }

    /// The input dtype every request must carry.
    pub fn input_dtype(&self) -> DType {
        self.dtype
    }

    /// Validate one `[example_dims…]` request input against the traced
    /// signature (the batcher rejects bad requests *before* they are
    /// stacked with innocent cohort requests).
    pub fn check_example(&self, example: &Tensor) -> Result<()> {
        if example.dims() != self.example_dims {
            return Err(Error::msg(format!(
                "serve: request shape {} != expected {:?}",
                example.shape(),
                self.example_dims
            )));
        }
        if example.dtype() != self.dtype {
            return Err(Error::msg(format!(
                "serve: request dtype {} != expected {}",
                example.dtype().name(),
                self.dtype.name()
            )));
        }
        Ok(())
    }

    /// Smallest compiled bucket that fits `n` rows.
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.bucket_index(n).map(|i| self.buckets[i].0)
    }

    /// Index (into the sorted bucket list) of the smallest bucket ≥ `n`.
    fn bucket_index(&self, n: usize) -> Option<usize> {
        self.buckets.iter().position(|(b, _)| *b >= n)
    }

    /// Run a `[n, example_dims…]` batch: route to the smallest bucket
    /// ≥ `n`, zero-pad the tail rows, execute the compiled program
    /// (donating the padded batch to the executor), and slice the real
    /// `n` rows back out of the output.
    pub fn run_batch(&self, batch: Tensor) -> Result<Tensor> {
        let dims = batch.dims().to_vec();
        if dims.len() != self.example_dims.len() + 1 || dims[1..] != self.example_dims[..] {
            return Err(Error::msg(format!(
                "serve: batch shape {} does not extend example dims {:?}",
                batch.shape(),
                self.example_dims
            )));
        }
        if batch.dtype() != self.dtype {
            return Err(Error::msg(format!(
                "serve: batch dtype {} != session dtype {}",
                batch.dtype().name(),
                self.dtype.name()
            )));
        }
        let n = dims[0];
        if n == 0 {
            return Err(Error::msg("serve: empty batch"));
        }
        let idx = self.bucket_index(n).ok_or_else(|| {
            Error::msg(format!(
                "serve: batch of {n} exceeds the largest compiled bucket ({})",
                self.max_batch()
            ))
        })?;
        let (bucket, program) = &self.buckets[idx];
        let bucket = *bucket;
        let mut run_span = crate::obs::span("serve.session.run_batch");
        run_span.attr_i64("n", n as i64);
        run_span.attr_i64("bucket", bucket as i64);
        run_span.attr_i64("pad_rows", (bucket - n) as i64);
        let padded = if bucket > n {
            let mut pad_dims = vec![bucket - n];
            pad_dims.extend_from_slice(&self.example_dims);
            let filler = Tensor::full(pad_dims, 0.0, self.dtype);
            Tensor::concat(&[&batch, &filler], 0)
        } else {
            batch
        };
        let (out, _stats) = program.call_owned(self.backend.as_ref(), vec![padded], true)?;
        Ok(if bucket > n { out.narrow(0, 0, n) } else { out })
    }

    /// Serve a single `[example_dims…]` example through the batch-1
    /// bucket path; returns the per-example output (no batch axis).
    pub fn run_one(&self, example: Tensor) -> Result<Tensor> {
        let mut dims: Vec<isize> = vec![1];
        dims.extend(example.dims().iter().map(|&d| d as isize));
        let out = self.run_batch(example.reshape(&dims))?;
        let rest: Vec<isize> = out.dims()[1..].iter().map(|&d| d as isize).collect();
        Ok(out.reshape(&rest))
    }

    /// Per-example output dims (without the batch axis).
    pub fn output_dims(&self) -> &[usize] {
        &self.out_rest
    }

    /// The backend every request runs on.
    pub fn backend(&self) -> &Arc<dyn TensorBackend> {
        &self.backend
    }
}
