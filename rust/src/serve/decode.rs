//! Bucket-compiled continuous-batching decode iterations.
//!
//! The continuous batcher's inner loop — one `[B, 1]` token step for `B`
//! cohabiting requests — is the hottest forward in the serving stack,
//! and until this module it ran eagerly while everything around it
//! (training steps, bucketed scoring forwards) went through the graph
//! compiler. The blocker was shape dynamism in the *middle* of the step:
//! every request sits at its own KV length with its own page table, so a
//! monolithic trace would either bake lengths in (re-trace every
//! iteration) or pad the KV gather (changing reduction widths and
//! breaking the bitwise-parity contract).
//!
//! The resolution is to compile the step as *segments* around the
//! attention cores. Per batch-size bucket `B`, [`CompiledDecodeStep`]
//! traces `depth + 1` multi-output programs over the same methods the
//! eager [`BertLike::logits_decode_batch`] runs:
//!
//! - **embed segment** `(ids [B,1] i64, positions [B] i64) → (h, q, k, v)`:
//!   token + positional embedding and layer 0's pre-attention half;
//! - **mid segment** per layer `(h, ctx) → (h', q', k', v')`: one layer's
//!   post-attention half (output projection, residuals, MLP) plus the
//!   next layer's pre-attention half;
//! - **head segment** `(h, ctx) → logits [B,1,V]`: the last layer's
//!   post-attention half, final norm, and LM head.
//!
//! Between segments the per-request attention cores (page write, past
//! gather, SDPA at each request's own length) run eagerly, exactly as
//! the eager path runs them. KV lengths and page tables therefore never
//! appear inside a traced program — only `ids` and `positions` are
//! substitutable inputs — so requests advancing through their sequences
//! never force a re-trace, and compiled-vs-eager bitwise parity is
//! structural: both paths execute the same op stream on the same values
//! (the compiler's passes are bit-preserving, which the graph fuzzer and
//! `rust/tests/serve.rs` pin).
//!
//! A batch smaller than its bucket is padded with token 0 at position 0;
//! pad rows get no attention core (they have no cache) — their contexts
//! are zero blocks — and their logits rows are sliced off. Row
//! independence of every traced op makes pad rows inert. A batch larger
//! than every bucket returns `None` (an observable *compile miss*) and
//! the caller falls back to the eager path.

use std::sync::Arc;

use crate::autograd::no_grad;
use crate::models::BertLike;
use crate::nn::PagedKvCache;
use crate::tensor::graph::{trace_and_compile_many, CompiledFn, CompiledInstr, CompiledProgram};
use crate::tensor::{DType, Op, Tensor, TensorBackend, ValueRef};
use crate::util::error::{Error, Result};

use super::session::quiesced_default_backend;

/// One bucket: the `depth + 1` compiled segment programs for a fixed
/// batch size.
struct DecodeBucket {
    size: usize,
    /// `[embed, mid(0), …, mid(depth-2), head]`.
    segs: Vec<CompiledFn>,
}

/// The continuous batcher's decode iteration, traced and compiled once
/// per batch-size bucket at startup (see the module docs for the segment
/// layout). Steady-state serving re-traces nothing: every iteration
/// whose batch fits a bucket runs the cached programs with fresh
/// `ids`/`positions`, and the per-request attention cores run eagerly
/// between segments.
pub struct CompiledDecodeStep {
    /// Ascending by batch size; a batch routes to the smallest bucket
    /// that fits.
    buckets: Vec<DecodeBucket>,
    backend: Arc<dyn TensorBackend>,
    heads: usize,
    head_dim: usize,
    vocab: usize,
}

/// Reject a compiled segment whose *outputs* depend on an RNG op. A
/// model traced in train mode (live dropout) would replay the trace-time
/// random stream on every call — silently wrong serving. Ops that are
/// captured but never reach an output (e.g. tensor work from another
/// thread caught by the process-global trace backend) are retained by
/// the compiler as effectful but harmless, so only reachable RNG is an
/// error.
fn check_rng_free(program: &CompiledProgram, what: &str) -> Result<()> {
    let mut needed = vec![false; program.instrs.len()];
    let mut stack: Vec<usize> = program
        .outputs
        .iter()
        .filter_map(|r| match r {
            ValueRef::Out(i) => Some(*i),
            ValueRef::Const(_) => None,
        })
        .collect();
    while let Some(i) = stack.pop() {
        if needed[i] {
            continue;
        }
        needed[i] = true;
        for r in program.instrs[i].inputs() {
            if let ValueRef::Out(j) = r {
                stack.push(*j);
            }
        }
    }
    for (i, instr) in program.instrs.iter().enumerate() {
        if !needed[i] {
            continue;
        }
        if let CompiledInstr::Op { op, .. } = instr {
            if matches!(op, Op::RandUniform { .. } | Op::RandNormal { .. }) {
                return Err(Error::msg(format!(
                    "serve: decode segment `{what}` traced an RNG op ({}); compile the decode \
                     step only for eval-mode models (dropout off)",
                    op.name()
                )));
            }
        }
    }
    Ok(())
}

impl CompiledDecodeStep {
    /// Trace and compile the decode step of `model` for every batch size
    /// in `bucket_sizes`. Tracing installs the capture backend
    /// process-globally (the same caveat as
    /// [`super::InferenceSession::compile`]): compile on a quiescent
    /// process, before serving threads start — the batcher does this on
    /// the caller's thread inside `ContinuousBatcher::start`, which is
    /// what makes startup the warmup.
    pub fn compile(model: &BertLike, bucket_sizes: &[usize]) -> Result<CompiledDecodeStep> {
        let mut sizes: Vec<usize> = bucket_sizes.to_vec();
        sizes.sort_unstable();
        sizes.dedup();
        if sizes.is_empty() || sizes[0] == 0 {
            return Err(Error::msg("serve: decode buckets must be non-empty and positive"));
        }
        let depth = model.depth();
        if depth == 0 {
            return Err(Error::msg("serve: decode compilation needs at least one layer"));
        }
        let (heads, head_dim, dim, vocab) =
            (model.heads(), model.head_dim(), model.dim(), model.vocab());
        let backend = quiesced_default_backend();
        let mut buckets = Vec::with_capacity(sizes.len());
        for &b in &sizes {
            let mut bucket_span = crate::obs::span("serve.decode.compile_bucket");
            bucket_span.attr_i64("batch", b as i64);
            bucket_span.attr_i64("segments", (depth + 1) as i64);
            let mut segs = Vec::with_capacity(depth + 1);
            let seg = no_grad(|| {
                let ex =
                    [Tensor::full([b, 1], 0.0, DType::I64), Tensor::full([b], 0.0, DType::I64)];
                trace_and_compile_many(&ex, |a| model.decode_seg_embed(&a[0], &a[1]))
            })
            .map_err(|e| Error::msg(format!("serve: decode bucket {b} embed segment: {e}")))?;
            segs.push(seg);
            for layer in 0..depth {
                let last = layer + 1 == depth;
                let seg = no_grad(|| {
                    let ex = [Tensor::zeros([b, 1, dim]), Tensor::zeros([b * heads, 1, head_dim])];
                    if last {
                        trace_and_compile_many(&ex, |a| {
                            vec![model.decode_seg_head(layer, &a[0], &a[1])]
                        })
                    } else {
                        trace_and_compile_many(&ex, |a| model.decode_seg_mid(layer, &a[0], &a[1]))
                    }
                })
                .map_err(|e| {
                    Error::msg(format!("serve: decode bucket {b} layer {layer} segment: {e}"))
                })?;
                segs.push(seg);
            }
            // validate each segment once: no reachable RNG, and a probe
            // run (the traced examples are still the programs' defaults)
            // confirming the segment interface shapes
            for (i, seg) in segs.iter().enumerate() {
                let what = seg_name(i, depth);
                check_rng_free(seg.program(), &format!("bucket {b} {what}"))?;
                let probe = seg.program().run(backend.as_ref())?;
                let expect: Vec<Vec<usize>> = if i == depth {
                    vec![vec![b, 1, vocab]]
                } else {
                    vec![
                        vec![b, 1, dim],
                        vec![b * heads, 1, head_dim],
                        vec![b * heads, 1, head_dim],
                        vec![b * heads, 1, head_dim],
                    ]
                };
                if probe.len() != expect.len()
                    || probe.iter().zip(&expect).any(|(t, e)| t.dims() != e.as_slice())
                {
                    return Err(Error::msg(format!(
                        "serve: decode bucket {b} {what} produced unexpected output shapes \
                         {:?} (want {expect:?})",
                        probe.iter().map(|t| t.dims().to_vec()).collect::<Vec<_>>()
                    )));
                }
            }
            buckets.push(DecodeBucket { size: b, segs });
        }
        Ok(CompiledDecodeStep { buckets, backend, heads, head_dim, vocab })
    }

    /// The compiled batch sizes, ascending.
    pub fn bucket_sizes(&self) -> Vec<usize> {
        self.buckets.iter().map(|b| b.size).collect()
    }

    /// Total compiled segment programs (`buckets × (depth + 1)`) — fixed
    /// at construction, which is how telemetry proves zero steady-state
    /// re-tracing.
    pub fn program_count(&self) -> usize {
        self.buckets.iter().map(|b| b.segs.len()).sum()
    }

    /// One compiled decode iteration: step each request in `caches` by
    /// its token in `tokens` (row `i` of both belongs to the same
    /// request), returning `[B, 1, V]` logits bit-identical to
    /// [`BertLike::logits_decode_batch`] over the same rows — or
    /// `Ok(None)` if no bucket fits (the caller's eager-fallback /
    /// `compile_misses` path).
    ///
    /// Caches advance only after every segment succeeded, and this
    /// step's K/V page writes are bitwise identical to the ones the
    /// eager path would make, so an `Err` mid-step leaves the caches
    /// safe for an eager retry of the same iteration.
    pub fn step(
        &self,
        model: &BertLike,
        tokens: &[i64],
        caches: &mut [&mut PagedKvCache],
    ) -> Result<Option<Tensor>> {
        let n = caches.len();
        assert_eq!(tokens.len(), n, "one token per KV cache");
        if n == 0 {
            return Ok(None);
        }
        let Some(bucket) = self.buckets.iter().find(|bk| bk.size >= n) else {
            return Ok(None);
        };
        let bsize = bucket.size;
        let depth = bucket.segs.len() - 1;
        let mut ids = tokens.to_vec();
        ids.resize(bsize, 0);
        let mut positions: Vec<i64> = caches.iter().map(|c| c.len() as i64).collect();
        positions.resize(bsize, 0);
        let ids = Tensor::from_slice(&ids, [bsize, 1]);
        let positions = Tensor::from_slice(&positions, [bsize]);
        let be = self.backend.as_ref();
        let (mut seg, _) = bucket.segs[0].call_owned_many(be, vec![ids, positions], true)?;
        for layer in 0..depth {
            let v = seg.pop().expect("segment interface: 4 outputs");
            let k = seg.pop().expect("segment interface: 4 outputs");
            let q = seg.pop().expect("segment interface: 4 outputs");
            let h = seg.pop().expect("segment interface: 4 outputs");
            let ctx_live = model.decode_attention_core(layer, &q, &k, &v, caches);
            let ctx = if bsize > n {
                let pad = Tensor::zeros([(bsize - n) * self.heads, 1, self.head_dim]);
                Tensor::concat(&[&ctx_live, &pad], 0)
            } else {
                ctx_live
            };
            let (next, _) = bucket.segs[layer + 1].call_owned_many(be, vec![h, ctx], true)?;
            seg = next;
        }
        let logits = seg.pop().expect("head segment: 1 output");
        debug_assert_eq!(logits.dims(), &[bsize, 1, self.vocab][..]);
        for c in caches.iter_mut() {
            c.advance(1);
        }
        Ok(Some(if bsize > n { logits.narrow(0, 0, n) } else { logits }))
    }
}

fn seg_name(i: usize, depth: usize) -> String {
    if i == 0 {
        "embed segment".to_string()
    } else if i == depth {
        "head segment".to_string()
    } else {
        format!("mid segment {}", i - 1)
    }
}
