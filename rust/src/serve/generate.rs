//! KV-cached autoregressive generation for the transformer LM.
//!
//! One prefill pass computes the prompt's keys/values per layer; each
//! subsequent token runs a single-position forward whose attention reads
//! the cache ([`crate::models::BertLike::logits_cached`]), so step cost is
//! O(L) instead of the O(L²) full recompute. Both paths exist here —
//! [`GenerateOptions::use_cache`] picks one — and they are
//! **bit-identical** on the reference CPU backend: the same prompt, seed,
//! and sampling settings produce the same tokens either way
//! (`rust/tests/serve.rs` asserts this over 64 generated tokens).
//!
//! Sampling is host-side and driven by an explicit
//! [`crate::util::rng::Rng`] stream seeded per call, so generation is
//! reproducible regardless of what other threads draw from the global
//! stream.

use std::time::Instant;

use crate::autograd::no_grad;
use crate::models::BertLike;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

/// Token-selection policy.
#[derive(Debug, Clone)]
pub enum Sampling {
    /// Pick the highest-logit token (first index on ties). Consumes no
    /// randomness.
    Greedy,
    /// Softmax over the `k` highest logits at `temperature`, then draw
    /// from that distribution (one uniform draw per token).
    TopK {
        /// How many candidates survive the cut.
        k: usize,
        /// Logit divisor; lower is sharper. Must be positive.
        temperature: f64,
    },
}

/// Decoding controls.
#[derive(Debug, Clone)]
pub struct GenerateOptions {
    /// How many tokens to append to the prompt.
    pub max_new_tokens: usize,
    /// Token-selection policy.
    pub sampling: Sampling,
    /// Seed of the per-call sampling stream.
    pub seed: u64,
    /// KV-cached incremental decode (true) or full-context recompute per
    /// token (false). Same bits either way; wildly different cost.
    pub use_cache: bool,
    /// Record the `[V]` logits each sampling step saw into
    /// [`GenerateReport::step_logits`]. Off by default (it clones one
    /// vocab-sized row per token); the schedule-fuzzing harness turns it
    /// on to compare continuous-batched decode against solo decode
    /// bit-for-bit, not just token-for-token.
    pub record_logits: bool,
}

impl Default for GenerateOptions {
    fn default() -> Self {
        GenerateOptions {
            max_new_tokens: 32,
            sampling: Sampling::Greedy,
            seed: 0,
            use_cache: true,
            record_logits: false,
        }
    }
}

/// What one generation call produced.
#[derive(Debug, Clone)]
pub struct GenerateReport {
    /// Prompt followed by the generated tokens.
    pub tokens: Vec<i64>,
    /// Tokens generated (== `max_new_tokens` unless the prompt filled the
    /// context).
    pub generated: usize,
    /// Seconds spent in the prefill pass (0 for the uncached path, which
    /// has no separate prefill). Under the continuous scheduler's chunked
    /// prefill this sums every chunk's forward time.
    pub prefill_secs: f64,
    /// Prefill forward passes this request ran: 1 for a whole-prompt
    /// prefill (the solo cached path and unchunked admissions), the chunk
    /// count for a chunked admission, 0 when no prefill ran (the uncached
    /// path, or `max_new_tokens == 0` under the scheduler).
    pub prefill_chunks: usize,
    /// Seconds spent decoding.
    pub decode_secs: f64,
    /// Generated tokens per decode second.
    pub tokens_per_sec: f64,
    /// Per-step pre-sampling logits (`[V]` per generated token), only
    /// when [`GenerateOptions::record_logits`] was set; empty otherwise.
    pub step_logits: Vec<Vec<f32>>,
    /// The request's observability timeline (admit → stalls → prefill
    /// chunks → per-token decode steps → retire), present when the
    /// request ran under the continuous scheduler with [`crate::obs`]
    /// enabled. `None` for solo generation and while obs is disabled.
    pub timeline: Option<crate::obs::RequestTrace>,
}

/// Generate `opts.max_new_tokens` continuation tokens for `prompt`.
pub fn generate(
    model: &BertLike,
    prompt: &[i64],
    opts: &GenerateOptions,
) -> Result<GenerateReport> {
    if prompt.is_empty() {
        return Err(Error::msg("generate: empty prompt"));
    }
    if prompt.len() + opts.max_new_tokens > model.max_len() {
        return Err(Error::msg(format!(
            "generate: prompt {} + {} new tokens exceeds the model's max_len {}",
            prompt.len(),
            opts.max_new_tokens,
            model.max_len()
        )));
    }
    if let Sampling::TopK { k, temperature } = &opts.sampling {
        if *k == 0 || !temperature.is_finite() || *temperature <= 0.0 {
            return Err(Error::msg(
                "generate: top-k sampling needs k > 0 and a positive finite temperature",
            ));
        }
    }
    let mut rng = Rng::new(opts.seed);
    let mut tokens = prompt.to_vec();
    let mut step_logits: Vec<Vec<f32>> = Vec::new();
    let (prefill_secs, decode_secs) = no_grad(|| {
        if opts.use_cache {
            let mut caches = model.empty_cache();
            let t0 = Instant::now();
            let ids = Tensor::from_slice(&tokens, [1, tokens.len()]);
            let prefill_logits = model.logits_cached(&ids, &mut caches).tensor();
            let mut last = last_position_logits(&prefill_logits);
            let prefill = t0.elapsed().as_secs_f64();
            let t1 = Instant::now();
            for i in 0..opts.max_new_tokens {
                if opts.record_logits {
                    step_logits.push(last.clone());
                }
                let next = sample(&last, &opts.sampling, &mut rng);
                tokens.push(next);
                if i + 1 < opts.max_new_tokens {
                    let step = Tensor::from_slice(&[next], [1, 1]);
                    last = model.logits_cached(&step, &mut caches).tensor().to_vec();
                }
            }
            (prefill, t1.elapsed().as_secs_f64())
        } else {
            let t0 = Instant::now();
            for _ in 0..opts.max_new_tokens {
                let ids = Tensor::from_slice(&tokens, [1, tokens.len()]);
                let last = last_position_logits(&model.logits(&ids).tensor());
                if opts.record_logits {
                    step_logits.push(last.clone());
                }
                tokens.push(sample(&last, &opts.sampling, &mut rng));
            }
            (0.0, t0.elapsed().as_secs_f64())
        }
    });
    let generated = tokens.len() - prompt.len();
    Ok(GenerateReport {
        generated,
        prefill_secs,
        prefill_chunks: if opts.use_cache { 1 } else { 0 },
        decode_secs,
        tokens_per_sec: if decode_secs > 0.0 { generated as f64 / decode_secs } else { 0.0 },
        tokens,
        step_logits,
        timeline: None,
    })
}

/// The `[V]` logits of the final position of a `[1, L, V]` logits tensor.
pub(super) fn last_position_logits(logits: &Tensor) -> Vec<f32> {
    let l = logits.dim(1);
    logits.narrow(1, l - 1, 1).to_vec()
}

/// Deterministic token selection over one position's logits. Shared with
/// the continuous scheduler so a batched request draws from the *same*
/// code path (and per-request RNG stream) as a solo decode.
pub(super) fn sample(logits: &[f32], sampling: &Sampling, rng: &mut Rng) -> i64 {
    match sampling {
        Sampling::Greedy => {
            let mut best = 0usize;
            for (i, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = i;
                }
            }
            best as i64
        }
        Sampling::TopK { k, temperature } => {
            let k = (*k).min(logits.len());
            // stable top-k: value descending, index ascending on ties
            let mut idx: Vec<usize> = (0..logits.len()).collect();
            idx.sort_by(|&a, &b| {
                logits[b]
                    .partial_cmp(&logits[a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            });
            idx.truncate(k);
            // f64 softmax over the survivors at the given temperature
            let scaled: Vec<f64> = idx.iter().map(|&i| logits[i] as f64 / temperature).collect();
            let m = scaled.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let weights: Vec<f64> = scaled.iter().map(|&s| (s - m).exp()).collect();
            let total: f64 = weights.iter().sum();
            let draw = rng.uniform() * total;
            let mut acc = 0.0;
            for (j, w) in weights.iter().enumerate() {
                acc += w;
                if draw < acc {
                    return idx[j] as i64;
                }
            }
            idx[k - 1] as i64
        }
    }
}
