//! Dynamic request batching over an MPSC queue.
//!
//! Requests enter a process-local queue; a pool of worker threads drains
//! it in batches. A worker that picks up a request waits at most
//! `max_wait` for companions (or until `max_batch_size` is reached),
//! stacks what arrived into one batch, and runs it through the
//! [`InferenceSession`]'s compiled programs. The deadline policy trades
//! a bounded latency penalty on the first request of a batch for the
//! throughput of batched execution.
//!
//! Correctness contract: batching is *invisible* — the response to a
//! request served in a batch of 8 is bit-identical to the same request
//! served alone (row-independent kernels + shape-bucket padding; enforced
//! by `rust/tests/serve.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::meter::{AverageValueMeter, PercentileMeter};
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};

use super::session::InferenceSession;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Largest batch one flush may assemble (clamped to the session's
    /// largest compiled bucket).
    pub max_batch_size: usize,
    /// How long the first request of a batch waits for companions.
    pub max_wait: Duration,
    /// Worker threads draining the queue.
    pub workers: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch_size: 8, max_wait: Duration::from_millis(2), workers: 2 }
    }
}

/// One queued request: the input example, its enqueue time (for latency
/// accounting), and where the response goes.
struct Request {
    input: Tensor,
    enqueued: Instant,
    resp: Sender<Result<Tensor>>,
    /// Per-request timeline (queued → score_batch → retire), allocated
    /// only while [`crate::obs`] is enabled. Scoring responses are bare
    /// tensors, so the finished timeline is published to the obs
    /// collector (Chrome-trace export) rather than returned.
    trace: Option<Box<crate::obs::RequestTrace>>,
}

/// The caller's handle to an in-flight request.
pub struct ResponseHandle {
    rx: Receiver<Result<Tensor>>,
}

impl ResponseHandle {
    /// Block until the response arrives (or the engine shut down with the
    /// request unserved).
    pub fn wait(self) -> Result<Tensor> {
        self.rx
            .recv()
            .map_err(|_| Error::msg("serve: engine shut down before the request was served"))?
    }
}

/// Shared counters and meters the workers update per batch.
#[derive(Default)]
struct Metrics {
    requests: AtomicU64,
    batches: AtomicU64,
    latency_us: Mutex<PercentileMeter>,
    batch_fill: Mutex<AverageValueMeter>,
}

/// A point-in-time snapshot of the batcher's telemetry.
#[derive(Debug, Clone, Default)]
pub struct BatcherStats {
    /// Requests answered.
    pub requests: u64,
    /// Program executions (batches flushed).
    pub batches: u64,
    /// Mean requests per flushed batch.
    pub mean_batch_fill: f64,
    /// Median request latency (enqueue → response), microseconds.
    pub latency_p50_us: f64,
    /// 95th-percentile request latency, microseconds.
    pub latency_p95_us: f64,
    /// 99th-percentile request latency, microseconds.
    pub latency_p99_us: f64,
}

/// The dynamic batcher: an MPSC queue plus a worker pool. Dropping (or
/// [`Batcher::shutdown`]) closes the queue; workers drain every request
/// already submitted, then exit, and the call blocks until they have.
///
/// `submit` may race `shutdown` from another thread: the sender lives
/// under a lock so a submit either lands before the queue closes (and is
/// served during the drain) or observes the closed queue and fails the
/// caller cleanly through [`ResponseHandle::wait`] — never a hang, never
/// a poisoned cohort (`rust/tests/serve.rs` exercises both orders).
pub struct Batcher {
    // submit() sends while holding the read lock; shutdown() takes the
    // sender under the write lock. A plain Option raced: a sender clone
    // taken between take() and join() would keep the channel connected
    // and leave the submitted request in a queue nobody drains.
    tx: RwLock<Option<Sender<Request>>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    metrics: Arc<Metrics>,
    session: Arc<InferenceSession>,
}

impl Batcher {
    /// Start `cfg.workers` threads serving through `session`.
    pub fn start(session: Arc<InferenceSession>, cfg: BatcherConfig) -> Batcher {
        let (tx, rx) = channel::<Request>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::default());
        let max_batch = cfg.max_batch_size.clamp(1, session.max_batch().max(1));
        let workers = (0..cfg.workers.max(1))
            .map(|i| {
                let rx = Arc::clone(&rx);
                let session = Arc::clone(&session);
                let metrics = Arc::clone(&metrics);
                let max_wait = cfg.max_wait;
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&rx, &session, max_batch, max_wait, &metrics))
                    .expect("serve: failed to spawn worker thread")
            })
            .collect();
        Batcher { tx: RwLock::new(Some(tx)), workers: Mutex::new(workers), metrics, session }
    }

    /// Enqueue one `[example_dims…]` input; returns immediately with a
    /// handle the caller can block on. Malformed inputs (wrong shape or
    /// dtype) are rejected here, before they can be stacked with — and
    /// poison — innocent cohort requests in the same batch.
    pub fn submit(&self, input: Tensor) -> ResponseHandle {
        let (rtx, rrx) = channel();
        if let Err(e) = self.session.check_example(&input) {
            let _ = rtx.send(Err(e));
            return ResponseHandle { rx: rrx };
        }
        let req = Request {
            input,
            enqueued: Instant::now(),
            resp: rtx,
            trace: crate::obs::RequestTrace::start(),
        };
        // send while holding the read lock: cloning the sender out of the
        // lock would keep the channel connected past shutdown's take(),
        // and the workers' drain-then-exit recv loop would never return
        let guard = self.tx.read().unwrap_or_else(|p| p.into_inner());
        if let Some(tx) = guard.as_ref() {
            let _ = tx.send(req);
        }
        // no sender: already shut down. Dropping `req` (and its response
        // sender with it) surfaces that through wait() as a clean error.
        ResponseHandle { rx: rrx }
    }

    /// Submit and block for the response.
    pub fn infer(&self, input: Tensor) -> Result<Tensor> {
        self.submit(input).wait()
    }

    /// Telemetry snapshot. Also publishes the snapshot into the
    /// process-wide metrics registry ([`crate::obs::metrics_snapshot`])
    /// under `serve.batcher.*`; with several batchers in one process the
    /// most recent publisher wins there, while each instance's own
    /// counters stay authoritative here.
    pub fn stats(&self) -> BatcherStats {
        let m = &self.metrics;
        let lat = m.latency_us.lock().unwrap_or_else(|p| p.into_inner());
        let fill = m.batch_fill.lock().unwrap_or_else(|p| p.into_inner());
        let stats = BatcherStats {
            requests: m.requests.load(Ordering::Relaxed),
            batches: m.batches.load(Ordering::Relaxed),
            mean_batch_fill: fill.value(),
            latency_p50_us: lat.p50(),
            latency_p95_us: lat.p95(),
            latency_p99_us: lat.p99(),
        };
        publish_batcher(&stats);
        stats
    }

    /// Graceful shutdown: stop accepting requests, serve everything
    /// already queued, join the workers. Idempotent, safe to race with
    /// [`Batcher::submit`]; also runs on drop.
    pub fn shutdown(&self) {
        let taken = self.tx.write().unwrap_or_else(|p| p.into_inner()).take();
        drop(taken); // disconnects the queue once no sender remains
        let workers: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.workers.lock().unwrap_or_else(|p| p.into_inner()));
        for w in workers {
            let _ = w.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    rx: &Mutex<Receiver<Request>>,
    session: &InferenceSession,
    max_batch: usize,
    max_wait: Duration,
    metrics: &Metrics,
) {
    loop {
        // hold the queue lock only while assembling one batch; a blocked
        // recv() parks this worker until traffic (or shutdown) arrives
        let mut batch: Vec<Request> = Vec::new();
        {
            let queue = rx.lock().unwrap_or_else(|p| p.into_inner());
            match queue.recv() {
                Ok(first) => {
                    // the deadline starts at pickup: under a backlog the
                    // companions are already queued and recv_timeout
                    // returns them without waiting, so a deep queue fills
                    // whole batches back-to-back
                    let deadline = Instant::now() + max_wait;
                    batch.push(first);
                    while batch.len() < max_batch {
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match queue.recv_timeout(deadline - now) {
                            Ok(r) => batch.push(r),
                            Err(RecvTimeoutError::Timeout | RecvTimeoutError::Disconnected) => {
                                break
                            }
                        }
                    }
                }
                // every sender dropped and the queue is drained: shutdown
                Err(_) => return,
            }
        }
        serve_batch(session, batch, metrics);
    }
}

/// Stack the collected requests, run them as one padded batch, and fan
/// the per-row outputs back to their callers.
fn serve_batch(session: &InferenceSession, mut batch: Vec<Request>, metrics: &Metrics) {
    let n = batch.len();
    // batch pickup ends each request's "queued" interval
    for req in batch.iter_mut() {
        if let Some(t) = req.trace.as_deref_mut() {
            t.admitted();
        }
    }
    let run_start_ns = batch.iter().any(|r| r.trace.is_some()).then(crate::obs::now_ns);
    let stacked = {
        let inputs: Vec<&Tensor> = batch.iter().map(|r| &r.input).collect();
        Tensor::stack(&inputs, 0)
    };
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batch_fill
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .add(n as f64);
    let mut batch_span = crate::obs::span("serve.batch");
    batch_span.attr_i64("batch", n as i64);
    let result = session.run_batch(stacked);
    drop(batch_span);
    match result {
        Ok(out) => {
            let rest: Vec<isize> = out.dims()[1..].iter().map(|&d| d as isize).collect();
            for (i, req) in batch.iter_mut().enumerate() {
                let row = out.narrow(0, i, 1).reshape(&rest);
                record_done(metrics, req, n as u32, run_start_ns);
                let _ = req.resp.send(Ok(row));
            }
        }
        Err(e) => {
            let msg = format!("serve: batch execution failed: {e}");
            for req in batch.iter_mut() {
                record_done(metrics, req, n as u32, run_start_ns);
                let _ = req.resp.send(Err(Error::msg(msg.clone())));
            }
        }
    }
}

fn record_done(metrics: &Metrics, req: &mut Request, batch: u32, run_start_ns: Option<u64>) {
    metrics.requests.fetch_add(1, Ordering::Relaxed);
    metrics
        .latency_us
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .add(req.enqueued.elapsed().as_secs_f64() * 1e6);
    if let Some(mut t) = req.trace.take() {
        if let Some(s) = run_start_ns {
            t.push("score_batch", s, batch, 0, false, 0);
        }
        // scoring responses are bare tensors with nowhere to carry the
        // timeline, so finish() publishes it to the collector for export
        let _ = crate::obs::RequestTrace::finish(t);
    }
}

/// Mirror a [`BatcherStats`] snapshot into the process-wide metrics
/// registry as absolute values.
fn publish_batcher(s: &BatcherStats) {
    use crate::obs::{counter, gauge};
    counter("serve.batcher.requests").set(s.requests);
    counter("serve.batcher.batches").set(s.batches);
    gauge("serve.batcher.mean_batch_fill").set(s.mean_batch_fill);
    gauge("serve.batcher.latency_p50_us").set(s.latency_p50_us);
    gauge("serve.batcher.latency_p95_us").set(s.latency_p95_us);
    gauge("serve.batcher.latency_p99_us").set(s.latency_p99_us);
}
