//! Inference serving: the request-answering runtime on top of the graph
//! compiler (the continuous-batching serving design of the Orca lineage,
//! scoped to this codebase's compiled forward programs).
//!
//! Four pieces, composable or standalone:
//!
//! - [`InferenceSession`] traces a model's forward pass in inference mode
//!   once per **shape bucket** via [`crate::tensor::trace_and_compile`]
//!   and serves every later request through the compiled programs —
//!   steady-state serving does zero re-tracing, and request batches are
//!   donated to the executor ([`crate::tensor::graph::CompiledFn::call_owned`])
//!   so their buffers recycle at last use.
//! - [`Batcher`] implements **dynamic batching**: an MPSC request queue
//!   drained by a worker pool under a `max_batch_size` + `max_wait`
//!   deadline policy, padding each flush up to the nearest compiled
//!   bucket. Correctness contract: a request served through a batch is
//!   **bit-identical** to the same request served alone
//!   (`rust/tests/serve.rs`).
//! - [`generate()`] is KV-cached autoregressive decoding for the
//!   transformer LM ([`crate::models::BertLike`]), with greedy and
//!   temperature/top-k sampling on deterministic
//!   [`crate::util::rng`] streams; cached decode is bit-identical to
//!   full-context recompute.
//! - [`ContinuousBatcher`] schedules decode at **iteration** granularity
//!   (the Orca design): the batch is re-formed every token, new requests
//!   join mid-flight right after their prefill (whole or Sarathi-style
//!   chunked, one chunk per pass), finished ones retire immediately, and
//!   each request's KV cache lives in fixed-size pages leased from a
//!   shared [`crate::memory::KvPagePool`] (admission backpressures on
//!   pool exhaustion instead of panicking). Contract: every request is
//!   bit-identical to its solo decode — fuzzed over randomized schedules
//!   by `rust/tests/serve_continuous_fuzz.rs`.
//! - [`CompiledDecodeStep`] compiles the batcher's per-token decode
//!   iteration once per batch-size bucket at startup (segments around
//!   the eager per-request attention cores, so KV lengths never enter a
//!   trace) — the hot serving loop runs compiled with zero steady-state
//!   re-tracing, bit-identical to the eager step, with an eager fallback
//!   counted as `compile_misses` telemetry.
//! - [`Engine`] ties them together: per-request latency percentiles
//!   ([`crate::meter::PercentileMeter`]), goodput and occupancy
//!   telemetry, and graceful worker shutdown (safe to race submits).
//!
//! The whole stack is instrumented through [`crate::obs`]: with
//! `FL_TRACE=1` (or [`crate::obs::set_enabled`]) every request carries a
//! [`crate::obs::RequestTrace`] timeline (admit → stalls → prefill
//! chunks → per-token decode steps → retire, surfaced on
//! [`GenerateReport::timeline`]), decode iterations and prefill chunks
//! record spans, and both batchers' `stats()` snapshots publish into the
//! process-wide metrics registry (`serve.*` names in
//! [`crate::obs::metrics_snapshot`]). Disabled — the default — the whole
//! layer costs one relaxed atomic load per checkpoint.

pub mod batcher;
pub mod decode;
pub mod engine;
pub mod generate;
pub mod scheduler;
pub mod session;

pub use batcher::{Batcher, BatcherConfig, BatcherStats, ResponseHandle};
pub use decode::CompiledDecodeStep;
pub use engine::{Engine, EngineConfig, EngineStats};
pub use generate::{generate, GenerateOptions, GenerateReport, Sampling};
pub use scheduler::{ContinuousBatcher, ContinuousConfig, ContinuousStats, GenHandle};
pub use session::InferenceSession;
