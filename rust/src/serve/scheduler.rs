//! Continuous (iteration-level) batching: the decode batch is re-formed
//! **every token**, in the Orca lineage.
//!
//! The PR 5 [`super::Batcher`] schedules at *request* granularity — a
//! decode cohort is locked until its slowest member finishes, so one
//! long generation head-of-line blocks every short one behind it. The
//! [`ContinuousBatcher`] schedules at *iteration* granularity instead:
//!
//! 1. **Intake** — drain newly submitted requests into a FIFO queue.
//! 2. **Admission** — while a decode slot is free, pop the queue head
//!    and reserve its worst-case KV pages (prompt + max new tokens) from
//!    the shared [`KvPagePool`]. Short prompts prefill inline, solo
//!    (`[1, L]` — the exact computation a solo decode would run); with
//!    [`ContinuousConfig::prefill_chunk`] set, longer prompts enter a
//!    *prefilling* state instead and run one fixed-size chunk per
//!    scheduling pass (Sarathi-style chunked prefill), so a huge
//!    admission no longer stalls every in-flight decode for a full
//!    prefill pass. If the pool cannot serve the reservation, the head
//!    *waits* (backpressure) until a retirement frees pages — admission
//!    is FIFO, so a starved request cannot be overtaken forever.
//! 3. **Iteration** — step every active sequence one token. The batched
//!    forward runs through [`super::CompiledDecodeStep`] when the batch
//!    size fits a pre-compiled bucket (the default; buckets compile once
//!    at [`ContinuousBatcher::start`], so steady state re-traces
//!    nothing), and falls back to the eager
//!    [`BertLike::logits_decode_batch`] otherwise — an observable
//!    *compile miss*. Both paths are bitwise identical. Each row samples
//!    on its own per-request RNG stream, and finished sequences
//!    **retire** immediately — their pages return to the pool the moment
//!    the cache drops, and the freed slot admits the next queued request
//!    on the very next iteration.
//!
//! Correctness bar (the repo's standing one): a request decoded through
//! this scheduler is `f32::to_bits`-identical to its solo decode —
//! token stream *and* per-step logits — regardless of who shared any of
//! its batches. `rust/tests/serve_continuous_fuzz.rs` fuzzes randomized
//! schedules against that contract; reservation-at-admission keeps the
//! schedule deterministic (a sequence can never stall mid-decode on an
//! empty pool, so batch composition depends only on arrival order and
//! retirement times, never on allocation luck).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::autograd::no_grad;
use crate::memory::{KvPagePool, KvPoolStats, PoolExhausted};
use crate::meter::{AverageValueMeter, PercentileMeter, TimeWeightedMeter};
use crate::models::BertLike;
use crate::nn::PagedKvCache;
use crate::tensor::Tensor;
use crate::util::error::{Error, Result};
use crate::util::rng::Rng;

use crate::obs::RequestTrace;

use super::decode::CompiledDecodeStep;
use super::generate::{last_position_logits, sample, GenerateOptions, GenerateReport, Sampling};

/// Continuous-scheduler knobs.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    /// Decode slots: the most sequences one iteration may step together.
    pub max_active: usize,
    /// KV positions per pool page. Small pages waste little memory on
    /// short sequences; large pages amortize page-table overhead.
    pub page_tokens: usize,
    /// Total pool pages. `None` sizes the pool for `max_active`
    /// worst-case (model `max_len`) sequences; smaller values trade
    /// admission backpressure for memory.
    pub pool_pages: Option<usize>,
    /// Batch-size buckets to pre-compile the decode iteration for at
    /// startup. `None` picks powers of two up to `max_active` plus
    /// `max_active` itself — with that set every feasible batch size
    /// fits a bucket, so steady state never misses. `Some(vec![])`
    /// disables compiled decode entirely (every iteration runs eagerly
    /// and counts as a miss).
    pub decode_buckets: Option<Vec<usize>>,
    /// Sarathi-style chunked prefill: prompts longer than this many
    /// tokens prefill in chunks of this size, one chunk per scheduling
    /// pass, interleaved with decode iterations. `None` prefills every
    /// prompt whole in one pass. Chunk boundaries cannot change any bits
    /// (the incremental-vs-recompute contract the KV cache already
    /// pins), only scheduling latency.
    pub prefill_chunk: Option<usize>,
}

impl Default for ContinuousConfig {
    fn default() -> Self {
        ContinuousConfig {
            max_active: 8,
            page_tokens: 16,
            pool_pages: None,
            decode_buckets: None,
            prefill_chunk: None,
        }
    }
}

/// A point-in-time snapshot of the scheduler's telemetry.
#[derive(Debug, Clone, Default)]
pub struct ContinuousStats {
    /// Requests accepted by [`ContinuousBatcher::submit`].
    pub submitted: u64,
    /// Requests answered (success or failure).
    pub completed: u64,
    /// Tokens generated across all requests.
    pub generated_tokens: u64,
    /// Batched decode iterations run.
    pub iterations: u64,
    /// Admissions that ran a prefill (every admission does).
    pub prefills: u64,
    /// Prefill forward passes run. Equal to `prefills` without chunking;
    /// with chunking each admission contributes one pass per chunk.
    pub prefill_chunks: u64,
    /// Admissions whose prefill was split into more than one chunk.
    pub chunked_admissions: u64,
    /// Decode iterations served by a pre-compiled bucket program.
    pub compiled_iterations: u64,
    /// Decode iterations that fell back to the eager path (no bucket
    /// fits, a compiled step failed, or compiled decode is disabled).
    /// `compiled_iterations + compile_misses == iterations`, always.
    pub compile_misses: u64,
    /// Compiled decode segment programs, fixed at startup
    /// (`buckets × (depth + 1)`; zero when compiled decode is disabled).
    /// Constant across the batcher's lifetime — the observable form of
    /// the zero-steady-state-re-tracing guarantee.
    pub decode_compiles: u64,
    /// Admissions deferred because the pool could not serve the
    /// reservation (each deferral counts once per scheduling pass).
    pub backpressure_stalls: u64,
    /// Seconds the scheduler spent inside model forwards.
    pub busy_secs: f64,
    /// Goodput: generated tokens per *busy* second (queue idle time
    /// excluded, so the number reflects scheduling efficiency, not
    /// traffic).
    pub goodput_tps: f64,
    /// Median request latency (submit → response), microseconds.
    pub latency_p50_us: f64,
    /// 95th-percentile request latency, microseconds.
    pub latency_p95_us: f64,
    /// 99th-percentile request latency, microseconds.
    pub latency_p99_us: f64,
    /// Mean sequences per decode iteration (observation-weighted).
    pub mean_iteration_batch: f64,
    /// Time-weighted mean decode-slot occupancy.
    pub occupancy_mean: f64,
    /// Peak decode-slot occupancy.
    pub occupancy_peak: f64,
    /// KV page-pool accounting.
    pub pool: KvPoolStats,
}

/// One queued generation request.
struct GenRequest {
    prompt: Vec<i64>,
    opts: GenerateOptions,
    resp: Sender<Result<GenerateReport>>,
    enqueued: Instant,
    /// Per-request timeline, allocated only while obs is enabled.
    trace: Option<Box<RequestTrace>>,
}

/// The caller's handle to an in-flight generation.
pub struct GenHandle {
    rx: Receiver<Result<GenerateReport>>,
}

impl GenHandle {
    /// Block until the report arrives (or the engine shut down with the
    /// request unserved).
    pub fn wait(self) -> Result<GenerateReport> {
        self.rx
            .recv()
            .map_err(|_| Error::msg("serve: engine shut down before the request was served"))?
    }
}

/// Shared counters and meters the scheduler thread updates.
#[derive(Default)]
struct SchedulerMetrics {
    submitted: AtomicU64,
    completed: AtomicU64,
    generated: AtomicU64,
    iterations: AtomicU64,
    prefills: AtomicU64,
    prefill_chunks: AtomicU64,
    chunked_admissions: AtomicU64,
    compiled_iters: AtomicU64,
    compile_misses: AtomicU64,
    stalls: AtomicU64,
    busy_nanos: AtomicU64,
    latency_us: Mutex<PercentileMeter>,
    batch_fill: Mutex<AverageValueMeter>,
    occupancy: Mutex<TimeWeightedMeter>,
}

/// One admitted, not-yet-finished sequence.
struct ActiveSeq {
    tokens: Vec<i64>,
    cache: PagedKvCache,
    rng: Rng,
    sampling: Sampling,
    max_new: usize,
    generated: usize,
    record: bool,
    step_logits: Vec<Vec<f32>>,
    /// The `[V]` logits of this sequence's latest position (what the next
    /// sample draws from).
    last: Vec<f32>,
    resp: Sender<Result<GenerateReport>>,
    enqueued: Instant,
    prefill_secs: f64,
    prefill_chunks: usize,
    decode_started: Instant,
    trace: Option<Box<RequestTrace>>,
}

/// An admitted sequence whose prompt is still prefilling, one chunk per
/// scheduling pass. Pages are already reserved (same worst-case
/// reservation as an inline admission), so chunking never changes the
/// backpressure schedule — only when the prefill compute happens.
struct PrefillingSeq {
    prompt: Vec<i64>,
    /// Prompt positions already written into the cache.
    filled: usize,
    chunk: usize,
    cache: PagedKvCache,
    opts: GenerateOptions,
    resp: Sender<Result<GenerateReport>>,
    enqueued: Instant,
    /// Prefill seconds summed across the chunks run so far.
    prefill_secs: f64,
    prefill_chunks: usize,
    trace: Option<Box<RequestTrace>>,
}

enum Admitted {
    /// Prefilled and sampling; joins the decode batch next iteration.
    Running(Box<ActiveSeq>),
    /// Admitted with pages reserved; prefilling chunk by chunk.
    Prefilling(Box<PrefillingSeq>),
    /// Finished at admission (`max_new_tokens == 1` needs no decode step).
    Done,
    /// The pool cannot serve the reservation yet; retry after retirements.
    Wait(GenRequest),
}

/// Outcome of one prefill chunk.
enum Prefilled {
    /// More prompt remains; run another chunk next pass.
    Still(Box<PrefillingSeq>),
    /// Prompt fully prefilled, last position's logits captured; the
    /// caller samples its first token and it joins the decode batch.
    Ready(Box<ActiveSeq>),
}

/// The continuous batcher: one scheduler thread owning the decode loop,
/// fed over an MPSC queue. Dropping (or [`ContinuousBatcher::shutdown`])
/// closes the queue; the scheduler drains every admitted *and* queued
/// request, then exits.
pub struct ContinuousBatcher {
    // submit() sends while holding the read lock; shutdown() takes the
    // sender under the write lock. An Option alone (the PR 5 batcher's
    // shape) races: a submit between take() and join() could clone a
    // live sender or enqueue into a queue nobody will drain.
    tx: RwLock<Option<Sender<GenRequest>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    metrics: Arc<SchedulerMetrics>,
    pool: Arc<KvPagePool>,
    model: Arc<BertLike>,
    /// Compiled decode segment programs (fixed at startup; see
    /// [`ContinuousStats::decode_compiles`]).
    decode_compiles: u64,
}

impl ContinuousBatcher {
    /// Start the scheduler thread for `model`. Decode buckets compile
    /// here, on the caller's thread, *before* the scheduler spawns —
    /// startup is the warmup, so the first live request never pays a
    /// trace+compile. (Tracing installs the capture backend
    /// process-globally; start batchers on a quiescent process.)
    pub fn start(model: Arc<BertLike>, cfg: &ContinuousConfig) -> Result<ContinuousBatcher> {
        if cfg.max_active == 0 {
            return Err(Error::msg("serve: continuous batching needs at least one decode slot"));
        }
        if cfg.page_tokens == 0 {
            return Err(Error::msg("serve: KV pages must hold at least one position"));
        }
        if cfg.prefill_chunk == Some(0) {
            return Err(Error::msg("serve: prefill chunks must hold at least one token"));
        }
        if model.depth() == 0 {
            return Err(Error::msg("serve: the model has no transformer layers to cache"));
        }
        let bucket_sizes: Vec<usize> = match &cfg.decode_buckets {
            Some(sizes) => sizes.clone(),
            None => {
                // powers of two below max_active, plus max_active: every
                // batch size the scheduler can form fits some bucket
                let mut sizes: Vec<usize> =
                    (0..).map(|i| 1usize << i).take_while(|&b| b < cfg.max_active).collect();
                sizes.push(cfg.max_active);
                sizes
            }
        };
        let compiled: Option<Arc<CompiledDecodeStep>> = if bucket_sizes.is_empty() {
            None
        } else {
            Some(Arc::new(CompiledDecodeStep::compile(&model, &bucket_sizes)?))
        };
        let decode_compiles = compiled.as_ref().map_or(0, |c| c.program_count() as u64);
        let per_seq = model.max_len().div_ceil(cfg.page_tokens);
        let pages = cfg.pool_pages.unwrap_or(cfg.max_active * per_seq).max(1);
        let pool = KvPagePool::new(model.kv_pool_config(cfg.page_tokens, pages));
        let metrics = Arc::new(SchedulerMetrics::default());
        let (tx, rx) = channel::<GenRequest>();
        let worker = {
            let model = Arc::clone(&model);
            let pool = Arc::clone(&pool);
            let metrics = Arc::clone(&metrics);
            let knobs = SchedulerKnobs {
                max_active: cfg.max_active,
                prefill_chunk: cfg.prefill_chunk,
                compiled,
            };
            std::thread::Builder::new()
                .name("serve-continuous".into())
                .spawn(move || scheduler_loop(&rx, &model, &pool, &knobs, &metrics))
                .map_err(|e| Error::msg(format!("serve: failed to spawn scheduler: {e}")))?
        };
        Ok(ContinuousBatcher {
            tx: RwLock::new(Some(tx)),
            worker: Mutex::new(Some(worker)),
            metrics,
            pool,
            model,
            decode_compiles,
        })
    }

    /// Enqueue one generation request; returns immediately with a handle
    /// the caller can block on. Malformed or never-servable requests
    /// (empty prompt, context overflow, bad sampling knobs, KV demand
    /// beyond the whole pool) fail fast here, without touching the queue.
    pub fn submit(&self, prompt: &[i64], opts: &GenerateOptions) -> GenHandle {
        let (rtx, rrx) = channel();
        let handle = GenHandle { rx: rrx };
        if let Err(e) = self.validate(prompt, opts) {
            let _ = rtx.send(Err(e));
            return handle;
        }
        if opts.max_new_tokens == 0 {
            // nothing to decode: answer immediately (a solo generate's
            // sampling loop never runs either, so the streams agree)
            self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            let _ = rtx.send(Ok(GenerateReport {
                tokens: prompt.to_vec(),
                generated: 0,
                prefill_secs: 0.0,
                prefill_chunks: 0,
                decode_secs: 0.0,
                tokens_per_sec: 0.0,
                step_logits: Vec::new(),
                timeline: None,
            }));
            return handle;
        }
        let req = GenRequest {
            prompt: prompt.to_vec(),
            opts: opts.clone(),
            resp: rtx,
            enqueued: Instant::now(),
            trace: RequestTrace::start(),
        };
        // send while holding the read lock: a sender clone escaping the
        // lock would keep the channel connected after shutdown() took the
        // original, and the scheduler would never see disconnect
        let guard = self.tx.read().unwrap_or_else(|p| p.into_inner());
        if let Some(tx) = guard.as_ref() {
            self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
            let _ = tx.send(req);
        }
        // no sender: shut down. Dropping `req` drops its response sender,
        // which surfaces as a clean error from GenHandle::wait().
        handle
    }

    /// Submit and block for the report.
    pub fn generate(&self, prompt: &[i64], opts: &GenerateOptions) -> Result<GenerateReport> {
        self.submit(prompt, opts).wait()
    }

    fn validate(&self, prompt: &[i64], opts: &GenerateOptions) -> Result<()> {
        if prompt.is_empty() {
            return Err(Error::msg("generate: empty prompt"));
        }
        if prompt.len() + opts.max_new_tokens > self.model.max_len() {
            return Err(Error::msg(format!(
                "generate: prompt {} + {} new tokens exceeds the model's max_len {}",
                prompt.len(),
                opts.max_new_tokens,
                self.model.max_len()
            )));
        }
        if let Sampling::TopK { k, temperature } = &opts.sampling {
            if *k == 0 || !temperature.is_finite() || *temperature <= 0.0 {
                return Err(Error::msg(
                    "generate: top-k sampling needs k > 0 and a positive finite temperature",
                ));
            }
        }
        let cfg = self.pool.config();
        let wanted = cfg.pages_for(prompt.len() + opts.max_new_tokens);
        if wanted > cfg.max_pages {
            // waiting could never help: this is a permanent rejection,
            // not backpressure
            return Err(PoolExhausted { wanted, free: 0, capacity: cfg.max_pages }.into());
        }
        Ok(())
    }

    /// Telemetry snapshot. Also publishes the snapshot into the
    /// process-wide [`crate::obs`] metrics registry (`serve.*` names), so
    /// `obs::metrics_snapshot()` is one source of truth; with several
    /// batchers alive the registry holds the most recent publisher's
    /// values, while each instance's own snapshot stays exact.
    pub fn stats(&self) -> ContinuousStats {
        let m = &self.metrics;
        let lat = m.latency_us.lock().unwrap_or_else(|p| p.into_inner());
        let fill = m.batch_fill.lock().unwrap_or_else(|p| p.into_inner());
        let occ = m.occupancy.lock().unwrap_or_else(|p| p.into_inner());
        let generated = m.generated.load(Ordering::Relaxed);
        let busy = m.busy_nanos.load(Ordering::Relaxed) as f64 / 1e9;
        let stats = ContinuousStats {
            submitted: m.submitted.load(Ordering::Relaxed),
            completed: m.completed.load(Ordering::Relaxed),
            generated_tokens: generated,
            iterations: m.iterations.load(Ordering::Relaxed),
            prefills: m.prefills.load(Ordering::Relaxed),
            prefill_chunks: m.prefill_chunks.load(Ordering::Relaxed),
            chunked_admissions: m.chunked_admissions.load(Ordering::Relaxed),
            compiled_iterations: m.compiled_iters.load(Ordering::Relaxed),
            compile_misses: m.compile_misses.load(Ordering::Relaxed),
            decode_compiles: self.decode_compiles,
            backpressure_stalls: m.stalls.load(Ordering::Relaxed),
            busy_secs: busy,
            goodput_tps: if busy > 0.0 { generated as f64 / busy } else { 0.0 },
            latency_p50_us: lat.p50(),
            latency_p95_us: lat.p95(),
            latency_p99_us: lat.p99(),
            mean_iteration_batch: fill.value(),
            occupancy_mean: occ.mean(),
            occupancy_peak: occ.peak(),
            pool: self.pool.stats(),
        };
        publish_continuous(&stats);
        stats
    }

    /// The shared KV page pool (its stats expose lease/release ledgers).
    pub fn pool(&self) -> &Arc<KvPagePool> {
        &self.pool
    }

    /// Graceful shutdown: stop accepting requests, let the scheduler
    /// drain everything already queued or in flight, join it. Idempotent,
    /// safe to race with [`Self::submit`], and also runs on drop.
    pub fn shutdown(&self) {
        let taken = self.tx.write().unwrap_or_else(|p| p.into_inner()).take();
        drop(taken); // disconnects the queue once no sender remains
        if let Some(w) = self.worker.lock().unwrap_or_else(|p| p.into_inner()).take() {
            let _ = w.join();
        }
    }
}

impl Drop for ContinuousBatcher {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The per-thread scheduler configuration `start()` hands the loop.
struct SchedulerKnobs {
    max_active: usize,
    prefill_chunk: Option<usize>,
    compiled: Option<Arc<CompiledDecodeStep>>,
}

fn scheduler_loop(
    rx: &Receiver<GenRequest>,
    model: &BertLike,
    pool: &Arc<KvPagePool>,
    knobs: &SchedulerKnobs,
    metrics: &SchedulerMetrics,
) {
    let mut pending: VecDeque<GenRequest> = VecDeque::new();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut prefilling: Vec<Box<PrefillingSeq>> = Vec::new();
    let mut disconnected = false;
    loop {
        // 1) intake: block only when idle, otherwise drain without waiting
        if active.is_empty() && prefilling.is_empty() && pending.is_empty() {
            if disconnected {
                break;
            }
            set_occupancy(metrics, 0.0);
            match rx.recv() {
                Ok(r) => pending.push_back(r),
                Err(_) => break,
            }
        }
        loop {
            match rx.try_recv() {
                Ok(r) => pending.push_back(r),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    disconnected = true;
                    break;
                }
            }
        }
        // 2) admission: FIFO; stop at the first head the pool can't
        // serve. Prefilling sequences hold decode slots — they will join
        // the batch, and slot-bounding them bounds chunked-prefill work
        // per pass.
        while active.len() + prefilling.len() < knobs.max_active {
            let Some(req) = pending.pop_front() else { break };
            match admit(model, pool, req, metrics, knobs.prefill_chunk) {
                Admitted::Running(seq) => active.push(*seq),
                Admitted::Prefilling(seq) => prefilling.push(seq),
                Admitted::Done => {}
                Admitted::Wait(mut req) => {
                    metrics.stalls.fetch_add(1, Ordering::Relaxed);
                    if let Some(t) = req.trace.as_deref_mut() {
                        t.mark_stalled();
                    }
                    if active.is_empty() && prefilling.is_empty() {
                        // every page is free yet the reservation failed —
                        // unreachable when submit() validated capacity,
                        // but fail loudly rather than livelock
                        let _ = req.resp.send(Err(Error::Memory(format!(
                            "serve: kv pool can never serve this request ({:?})",
                            pool.stats()
                        ))));
                        metrics.completed.fetch_add(1, Ordering::Relaxed);
                    } else {
                        pending.push_front(req);
                    }
                    break;
                }
            }
        }
        // 2b) chunked prefill: advance each prefilling sequence one
        // chunk, interleaved with the decode iteration below so a long
        // prompt never monopolizes a pass
        if !prefilling.is_empty() {
            let mut still = Vec::with_capacity(prefilling.len());
            for p in prefilling.drain(..) {
                match prefill_chunk_step(model, p, metrics) {
                    Prefilled::Still(p) => still.push(p),
                    Prefilled::Ready(mut seq) => {
                        step_seq(&mut seq, 0, 0, false);
                        if seq.generated >= seq.max_new {
                            retire(*seq, metrics);
                        } else {
                            active.push(*seq);
                        }
                    }
                }
            }
            prefilling = still;
        }
        if active.is_empty() {
            continue;
        }
        // 3) one iteration: step every active sequence one token
        set_occupancy(metrics, (active.len() + prefilling.len()) as f64);
        metrics
            .batch_fill
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .add(active.len() as f64);
        let t0 = Instant::now();
        // one enabled() check per iteration; the disabled path pays
        // nothing else (no clock reads, no bucket lookup)
        let tracing = crate::obs::enabled();
        let batch = active.len();
        let bucket: u32 = if tracing {
            knobs
                .compiled
                .as_ref()
                .and_then(|cs| cs.bucket_sizes().into_iter().find(|&b| b >= batch))
                .unwrap_or(0) as u32
        } else {
            0
        };
        let mut iter_span = crate::obs::span("serve.decode.iter");
        iter_span.attr_i64("batch", batch as i64);
        iter_span.attr_i64("bucket", bucket as i64);
        let last_tokens: Vec<i64> =
            active.iter().map(|s| *s.tokens.last().expect("nonempty prompt")).collect();
        let (logits, compiled_iter) = {
            let mut caches: Vec<&mut PagedKvCache> =
                active.iter_mut().map(|s| &mut s.cache).collect();
            // compiled first; any miss (no bucket, a failed step, or
            // compiled decode disabled) falls back to the bit-identical
            // eager path — the iteration always completes
            let compiled_out: Option<Tensor> = knobs.compiled.as_ref().and_then(|cs| {
                no_grad(|| cs.step(model, &last_tokens, &mut caches)).ok().flatten()
            });
            match compiled_out {
                Some(t) => {
                    metrics.compiled_iters.fetch_add(1, Ordering::Relaxed);
                    (t, true)
                }
                None => {
                    metrics.compile_misses.fetch_add(1, Ordering::Relaxed);
                    let ids = Tensor::from_slice(&last_tokens, [active.len(), 1]);
                    (no_grad(|| model.logits_decode_batch(&ids, &mut caches)).tensor(), false)
                }
            }
        };
        iter_span.attr_str("mode", if compiled_iter { "compiled" } else { "eager" });
        drop(iter_span);
        metrics.busy_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let v = logits.dim(2);
        let flat = logits.to_vec();
        let mut i = 0;
        while i < active.len() {
            active[i].last = flat[i * v..(i + 1) * v].to_vec();
            step_seq(&mut active[i], batch as u32, bucket, compiled_iter);
            if active[i].generated >= active[i].max_new {
                // swap_remove: retirement is O(1) and batch order carries
                // no meaning (every row is bitwise independent)
                let seq = active.swap_remove(i);
                retire(seq, metrics);
            } else {
                i += 1;
            }
        }
        metrics.iterations.fetch_add(1, Ordering::Relaxed);
    }
    set_occupancy(metrics, 0.0);
}

/// Reserve pages, prefill, and sample the first token — the admission
/// path. Mirrors `generate()`'s cached branch exactly: prefill produces
/// the last position's logits, the first sample draws from them, and a
/// forward only runs for tokens after the first. Prompts longer than
/// `prefill_chunk` defer their prefill to [`prefill_chunk_step`] instead
/// (pages stay reserved either way).
fn admit(
    model: &BertLike,
    pool: &Arc<KvPagePool>,
    mut req: GenRequest,
    metrics: &SchedulerMetrics,
    prefill_chunk: Option<usize>,
) -> Admitted {
    let mut cache = PagedKvCache::new(Arc::clone(pool));
    if cache.reserve(req.prompt.len() + req.opts.max_new_tokens).is_err() {
        return Admitted::Wait(req);
    }
    if let Some(t) = req.trace.as_deref_mut() {
        t.admitted();
    }
    metrics.prefills.fetch_add(1, Ordering::Relaxed);
    if let Some(chunk) = prefill_chunk {
        if req.prompt.len() > chunk {
            metrics.chunked_admissions.fetch_add(1, Ordering::Relaxed);
            return Admitted::Prefilling(Box::new(PrefillingSeq {
                prompt: req.prompt,
                filled: 0,
                chunk,
                cache,
                opts: req.opts,
                resp: req.resp,
                enqueued: req.enqueued,
                prefill_secs: 0.0,
                prefill_chunks: 0,
                trace: req.trace,
            }));
        }
    }
    metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
    let start_ns = req.trace.as_ref().map(|_| crate::obs::now_ns());
    let mut sp = crate::obs::span("serve.prefill_chunk");
    sp.attr_i64("tokens", req.prompt.len() as i64);
    let t0 = Instant::now();
    let ids = Tensor::from_slice(&req.prompt, [1, req.prompt.len()]);
    let logits = no_grad(|| model.logits_paged(&ids, &mut cache)).tensor();
    drop(sp);
    let last = last_position_logits(&logits);
    let prefill_secs = t0.elapsed().as_secs_f64();
    metrics.busy_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    if let (Some(t), Some(s)) = (req.trace.as_deref_mut(), start_ns) {
        t.push("prefill_chunk", s, 1, 0, false, req.prompt.len() as u32);
    }
    let mut seq = Box::new(ActiveSeq {
        tokens: req.prompt,
        cache,
        rng: Rng::new(req.opts.seed),
        sampling: req.opts.sampling.clone(),
        max_new: req.opts.max_new_tokens,
        generated: 0,
        record: req.opts.record_logits,
        step_logits: Vec::new(),
        last,
        resp: req.resp,
        enqueued: req.enqueued,
        prefill_secs,
        prefill_chunks: 1,
        decode_started: Instant::now(),
        trace: req.trace,
    });
    step_seq(&mut seq, 0, 0, false);
    if seq.generated >= seq.max_new {
        retire(*seq, metrics);
        Admitted::Done
    } else {
        Admitted::Running(seq)
    }
}

/// Run one prefill chunk for a [`PrefillingSeq`]: forward the next
/// `chunk` prompt tokens (fewer on the final chunk) against the
/// request's paged cache — the same `[1, L]` incremental forward a solo
/// `generate()` would run, so chunk boundaries change no bits (each
/// position's causal-bias row and gathered past are identical however
/// the prompt is split). The final chunk's logits end at the prompt's
/// last position, exactly what a whole-prompt prefill returns.
fn prefill_chunk_step(
    model: &BertLike,
    mut p: Box<PrefillingSeq>,
    metrics: &SchedulerMetrics,
) -> Prefilled {
    let take = p.chunk.min(p.prompt.len() - p.filled);
    metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
    let start_ns = p.trace.as_ref().map(|_| crate::obs::now_ns());
    let mut sp = crate::obs::span("serve.prefill_chunk");
    sp.attr_i64("tokens", take as i64);
    sp.attr_i64("filled", p.filled as i64);
    let t0 = Instant::now();
    let ids = Tensor::from_slice(&p.prompt[p.filled..p.filled + take], [1, take]);
    let logits = no_grad(|| model.logits_paged(&ids, &mut p.cache)).tensor();
    drop(sp);
    p.prefill_secs += t0.elapsed().as_secs_f64();
    metrics.busy_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
    p.prefill_chunks += 1;
    p.filled += take;
    if let (Some(t), Some(s)) = (p.trace.as_deref_mut(), start_ns) {
        t.push("prefill_chunk", s, 1, 0, false, take as u32);
    }
    if p.filled < p.prompt.len() {
        return Prefilled::Still(p);
    }
    let last = last_position_logits(&logits);
    Prefilled::Ready(Box::new(ActiveSeq {
        tokens: p.prompt,
        cache: p.cache,
        rng: Rng::new(p.opts.seed),
        sampling: p.opts.sampling.clone(),
        max_new: p.opts.max_new_tokens,
        generated: 0,
        record: p.opts.record_logits,
        step_logits: Vec::new(),
        last,
        resp: p.resp,
        enqueued: p.enqueued,
        prefill_secs: p.prefill_secs,
        prefill_chunks: p.prefill_chunks,
        decode_started: Instant::now(),
        trace: p.trace,
    }))
}

/// Sample the next token from `seq.last` — the same `sample()` a solo
/// decode runs, on the request's own RNG stream. The timeline records
/// one `"sample"` event per generated token (the telemetry-balance
/// oracle): `batch == 0` marks the first token, drawn from prefill
/// logits rather than a decode iteration; later tokens carry their
/// iteration's batch / bucket / compiled context.
fn step_seq(seq: &mut ActiveSeq, batch: u32, bucket: u32, compiled: bool) {
    if seq.record {
        seq.step_logits.push(seq.last.clone());
    }
    let next = sample(&seq.last, &seq.sampling, &mut seq.rng);
    seq.tokens.push(next);
    seq.generated += 1;
    if let Some(t) = seq.trace.as_deref_mut() {
        let now = crate::obs::now_ns();
        t.push("sample", now, batch, bucket, compiled, 1);
    }
}

/// Finish a sequence: build its report, answer the caller, account the
/// telemetry. The cache drops here, returning every page to the pool.
fn retire(seq: ActiveSeq, metrics: &SchedulerMetrics) {
    let decode_secs = seq.decode_started.elapsed().as_secs_f64();
    metrics.completed.fetch_add(1, Ordering::Relaxed);
    metrics.generated.fetch_add(seq.generated as u64, Ordering::Relaxed);
    metrics
        .latency_us
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .add(seq.enqueued.elapsed().as_secs_f64() * 1e6);
    // finish() publishes a copy to the collector for Chrome export; the
    // original rides on the report
    let timeline = seq.trace.map(RequestTrace::finish);
    let report = GenerateReport {
        generated: seq.generated,
        prefill_secs: seq.prefill_secs,
        prefill_chunks: seq.prefill_chunks,
        decode_secs,
        tokens_per_sec: if decode_secs > 0.0 { seq.generated as f64 / decode_secs } else { 0.0 },
        tokens: seq.tokens,
        step_logits: seq.step_logits,
        timeline,
    };
    let _ = seq.resp.send(Ok(report));
}

/// Publish a [`ContinuousStats`] snapshot into the obs metrics registry.
/// Counters are absolute `set`s (the scheduler already counts
/// per-instance); gauges carry the derived rates and pool occupancy.
fn publish_continuous(s: &ContinuousStats) {
    use crate::obs::{counter, gauge};
    counter("serve.requests.submitted").set(s.submitted);
    counter("serve.requests.completed").set(s.completed);
    counter("serve.decode.iterations").set(s.iterations);
    counter("serve.decode.compiled_iterations").set(s.compiled_iterations);
    counter("serve.decode.compile_misses").set(s.compile_misses);
    counter("serve.decode.generated_tokens").set(s.generated_tokens);
    counter("serve.decode.compiles").set(s.decode_compiles);
    counter("serve.prefill.count").set(s.prefills);
    counter("serve.prefill.chunks").set(s.prefill_chunks);
    counter("serve.prefill.chunked_admissions").set(s.chunked_admissions);
    counter("serve.backpressure_stalls").set(s.backpressure_stalls);
    gauge("serve.decode.goodput_tps").set(s.goodput_tps);
    gauge("serve.decode.mean_iteration_batch").set(s.mean_iteration_batch);
    gauge("serve.decode.busy_secs").set(s.busy_secs);
    gauge("serve.latency_p50_us").set(s.latency_p50_us);
    gauge("serve.latency_p95_us").set(s.latency_p95_us);
    gauge("serve.latency_p99_us").set(s.latency_p99_us);
    gauge("serve.occupancy_mean").set(s.occupancy_mean);
    gauge("serve.occupancy_peak").set(s.occupancy_peak);
    gauge("serve.pool.leased_pages").set(s.pool.leased_pages as f64);
    gauge("serve.pool.peak_leased_pages").set(s.pool.peak_leased_pages as f64);
}

fn set_occupancy(metrics: &SchedulerMetrics, level: f64) {
    metrics.occupancy.lock().unwrap_or_else(|p| p.into_inner()).set(level);
}
