//! Composable dataset pipeline (paper §4.2 "Data Loaders").
//!
//! A *sample* is a `Vec<Tensor>` (e.g. `[input, target]`). Datasets are
//! trivially composable to transform, resample, batch, or parallelize
//! (via native threads — [`PrefetchDataset`]) the construction of samples.

pub mod batch;
pub mod prefetch;
pub mod shuffle;
pub mod transform;

pub use batch::BatchDataset;
pub use prefetch::PrefetchDataset;
pub use shuffle::ShuffleDataset;
pub use transform::TransformDataset;

use std::sync::Arc;

use crate::tensor::Tensor;

/// A sample: one or more tensors.
pub type Sample = Vec<Tensor>;

/// The dataset interface. Implementations must be cheap to `get` in any
/// order and thread-safe (prefetchers call from worker threads).
pub trait Dataset: Send + Sync {
    /// Number of samples.
    fn len(&self) -> usize;
    /// Fetch sample `i` (`i < len`).
    fn get(&self, i: usize) -> Sample;
    /// Is the dataset empty?
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Iterate a dataset in order (paper Listing 9's `for example in dataset`).
pub struct DataIter {
    ds: Arc<dyn Dataset>,
    i: usize,
}

impl Iterator for DataIter {
    type Item = Sample;
    fn next(&mut self) -> Option<Sample> {
        if self.i >= self.ds.len() {
            return None;
        }
        let s = self.ds.get(self.i);
        self.i += 1;
        Some(s)
    }
}

/// Convenience: iterate any dataset.
pub fn iter(ds: Arc<dyn Dataset>) -> DataIter {
    DataIter { ds, i: 0 }
}

/// In-memory dataset over column tensors: sample `i` is the `i`-th slice
/// of each tensor along axis 0 (paper Listing 7's `TensorDataset`).
pub struct TensorDataset {
    columns: Vec<Tensor>,
    n: usize,
}

impl TensorDataset {
    /// All columns must share their first dimension.
    pub fn new(columns: Vec<Tensor>) -> Self {
        assert!(!columns.is_empty(), "TensorDataset needs at least one column");
        let n = columns[0].dim(0);
        for c in &columns {
            assert_eq!(c.dim(0), n, "column length mismatch");
        }
        TensorDataset { columns, n }
    }
}

impl Dataset for TensorDataset {
    fn len(&self) -> usize {
        self.n
    }

    fn get(&self, i: usize) -> Sample {
        assert!(i < self.n, "index {i} out of range {}", self.n);
        self.columns.iter().map(|c| c.narrow(0, i, 1)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn tensor_dataset_slices_rows() {
        let x = Tensor::arange(12, DType::F32).reshape(&[4, 3]);
        let y = Tensor::from_slice(&[0i64, 1, 2, 3], [4]);
        let ds = TensorDataset::new(vec![x, y]);
        assert_eq!(ds.len(), 4);
        let s = ds.get(2);
        assert_eq!(s[0].dims(), &[1, 3]);
        assert_eq!(s[0].to_vec(), vec![6.0, 7.0, 8.0]);
        assert_eq!(s[1].to_vec_i64(), vec![2]);
    }

    #[test]
    fn iterator_walks_all() {
        let x = Tensor::arange(5, DType::F32).reshape(&[5, 1]);
        let ds: Arc<dyn Dataset> = Arc::new(TensorDataset::new(vec![x]));
        let seen: Vec<f32> = iter(ds).map(|s| s[0].to_vec()[0]).collect();
        assert_eq!(seen, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
    }
}
