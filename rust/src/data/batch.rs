//! Batching dataset (paper Listing 7's `BatchDataset`).

use std::sync::Arc;

use crate::tensor::Tensor;

use super::{Dataset, Sample};

/// Groups consecutive samples into batches by concatenating each column
/// along axis 0. The final partial batch is kept (like the original
/// library's default batching policy).
pub struct BatchDataset {
    inner: Arc<dyn Dataset>,
    batch_size: usize,
}

impl BatchDataset {
    /// Batch `inner` into groups of `batch_size`.
    pub fn new(inner: Arc<dyn Dataset>, batch_size: usize) -> Self {
        assert!(batch_size > 0);
        BatchDataset { inner, batch_size }
    }

    /// Configured batch size.
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }
}

impl Dataset for BatchDataset {
    fn len(&self) -> usize {
        self.inner.len().div_ceil(self.batch_size)
    }

    fn get(&self, i: usize) -> Sample {
        let start = i * self.batch_size;
        let end = (start + self.batch_size).min(self.inner.len());
        assert!(start < end, "batch index {i} out of range");
        let samples: Vec<Sample> = (start..end).map(|j| self.inner.get(j)).collect();
        let cols = samples[0].len();
        (0..cols)
            .map(|c| {
                let parts: Vec<&Tensor> = samples.iter().map(|s| &s[c]).collect();
                Tensor::concat(&parts, 0)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TensorDataset;
    use crate::tensor::DType;

    #[test]
    fn batches_and_partial_tail() {
        let x = Tensor::arange(10, DType::F32).reshape(&[10, 1]);
        let ds = BatchDataset::new(Arc::new(TensorDataset::new(vec![x])), 4);
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.get(0)[0].dims(), &[4, 1]);
        assert_eq!(ds.get(2)[0].dims(), &[2, 1]); // partial tail
        assert_eq!(ds.get(2)[0].to_vec(), vec![8.0, 9.0]);
    }

    #[test]
    fn multiple_columns_stay_aligned() {
        let x = Tensor::arange(6, DType::F32).reshape(&[6, 1]);
        let y = Tensor::from_slice(&[10i64, 11, 12, 13, 14, 15], [6]);
        let ds = BatchDataset::new(Arc::new(TensorDataset::new(vec![x, y])), 3);
        let b = ds.get(1);
        assert_eq!(b[0].to_vec(), vec![3.0, 4.0, 5.0]);
        assert_eq!(b[1].to_vec_i64(), vec![13, 14, 15]);
    }
}
