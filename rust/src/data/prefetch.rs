//! Thread-parallel prefetching (paper §4.2: datasets "parallelize (via
//! native C++ threads) the construction of samples").
//!
//! `PrefetchDataset` keeps a sliding window of in-flight samples computed
//! by a worker pool, so expensive transforms (augmentation, featurization)
//! overlap with training compute.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

use super::{Dataset, Sample};

/// Sequential-access prefetcher: wraps an inner dataset and computes up to
/// `ahead` samples in advance on `workers` threads.
pub struct PrefetchDataset {
    inner: Arc<dyn Dataset>,
    workers: usize,
    ahead: usize,
}

impl PrefetchDataset {
    /// Prefetch up to `ahead` samples using `workers` threads.
    pub fn new(inner: Arc<dyn Dataset>, workers: usize, ahead: usize) -> Self {
        PrefetchDataset { inner, workers: workers.max(1), ahead: ahead.max(1) }
    }

    /// Iterate the dataset in order with background prefetching. The
    /// returned iterator owns the worker pool for its lifetime.
    pub fn iter(&self) -> PrefetchIter {
        let n = self.inner.len();
        let (task_tx, task_rx) = mpsc::channel::<usize>();
        let task_rx = Arc::new(std::sync::Mutex::new(task_rx));
        let (done_tx, done_rx) = mpsc::channel::<(usize, Sample)>();
        let mut handles = Vec::new();
        for _ in 0..self.workers {
            let rx = task_rx.clone();
            let tx = done_tx.clone();
            let ds = self.inner.clone();
            handles.push(std::thread::spawn(move || {
                loop {
                    let idx = { rx.lock().unwrap().recv() };
                    match idx {
                        Ok(i) => {
                            if tx.send((i, ds.get(i))).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        // seed the window
        let mut submitted = 0usize;
        while submitted < self.ahead.min(n) {
            task_tx.send(submitted).unwrap();
            submitted += 1;
        }
        PrefetchIter {
            n,
            next: 0,
            submitted,
            task_tx: Some(task_tx),
            done_rx,
            ready: HashMap::new(),
            handles,
        }
    }
}

impl Dataset for PrefetchDataset {
    fn len(&self) -> usize {
        self.inner.len()
    }
    /// Random access falls through to the inner dataset (no prefetch).
    fn get(&self, i: usize) -> Sample {
        self.inner.get(i)
    }
}

/// Ordered iterator with a live worker pool.
pub struct PrefetchIter {
    n: usize,
    next: usize,
    submitted: usize,
    task_tx: Option<mpsc::Sender<usize>>,
    done_rx: mpsc::Receiver<(usize, Sample)>,
    ready: HashMap<usize, Sample>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Iterator for PrefetchIter {
    type Item = Sample;

    fn next(&mut self) -> Option<Sample> {
        if self.next >= self.n {
            return None;
        }
        // drain completions until the in-order sample arrives
        while !self.ready.contains_key(&self.next) {
            let (i, s) = self.done_rx.recv().expect("prefetch worker died");
            self.ready.insert(i, s);
        }
        let out = self.ready.remove(&self.next).unwrap();
        self.next += 1;
        if self.submitted < self.n {
            if let Some(tx) = &self.task_tx {
                tx.send(self.submitted).ok();
                self.submitted += 1;
            }
        }
        Some(out)
    }
}

impl Drop for PrefetchIter {
    fn drop(&mut self) {
        // closing the task channel stops the workers
        self.task_tx.take();
        // drain to unblock senders
        while self.done_rx.try_recv().is_ok() {}
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorDataset, TransformDataset};
    use crate::tensor::{DType, Tensor};

    #[test]
    fn preserves_order_with_parallel_workers() {
        let x = Tensor::arange(64, DType::F32).reshape(&[64, 1]);
        let slow = TransformDataset::new(Arc::new(TensorDataset::new(vec![x])), |s| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            s
        });
        let pf = PrefetchDataset::new(Arc::new(slow), 4, 8);
        let got: Vec<f32> = pf.iter().map(|s| s[0].to_vec()[0]).collect();
        assert_eq!(got, (0..64).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn prefetch_overlaps_work() {
        use std::time::Instant;
        let x = Tensor::arange(32, DType::F32).reshape(&[32, 1]);
        let make = || {
            TransformDataset::new(Arc::new(TensorDataset::new(vec![x.clone()])), |s| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                s
            })
        };
        let t0 = Instant::now();
        let serial: usize = (0..32).map(|i| make().get(i).len()).sum();
        let serial_time = t0.elapsed();
        let pf = PrefetchDataset::new(Arc::new(make()), 8, 16);
        let t1 = Instant::now();
        let par: usize = pf.iter().map(|s| s.len()).sum();
        let par_time = t1.elapsed();
        assert_eq!(serial, par);
        assert!(
            par_time < serial_time,
            "prefetch ({par_time:?}) not faster than serial ({serial_time:?})"
        );
    }

    #[test]
    fn drop_mid_iteration_is_clean() {
        let x = Tensor::arange(100, DType::F32).reshape(&[100, 1]);
        let pf = PrefetchDataset::new(Arc::new(TensorDataset::new(vec![x])), 2, 4);
        let mut it = pf.iter();
        let _ = it.next();
        drop(it); // must not hang or panic
    }
}
