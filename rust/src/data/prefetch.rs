//! Thread-parallel prefetching (paper §4.2: datasets "parallelize (via
//! native C++ threads) the construction of samples").
//!
//! `PrefetchDataset` keeps a sliding window of in-flight samples computed
//! by a worker pool, so expensive transforms (augmentation, featurization)
//! overlap with training compute.
//!
//! A panic inside `Dataset::get` on a worker is caught and surfaced as a
//! typed error naming the failed sample (via [`PrefetchIter::try_next`];
//! the plain [`Iterator`] re-panics with the same label). The pool
//! survives the failure, so iteration can continue past a bad sample.

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::Arc;

use super::{Dataset, Sample};
use crate::util::error::{Error, Result};

/// What a worker sends back per sample: the sample, or the panic message
/// from the inner dataset's `get`.
type WorkerItem = std::result::Result<Sample, String>;

/// Best-effort extraction of a panic payload's message.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Sequential-access prefetcher: wraps an inner dataset and computes up to
/// `ahead` samples in advance on `workers` threads.
pub struct PrefetchDataset {
    inner: Arc<dyn Dataset>,
    workers: usize,
    ahead: usize,
}

impl PrefetchDataset {
    /// Prefetch up to `ahead` samples using `workers` threads.
    pub fn new(inner: Arc<dyn Dataset>, workers: usize, ahead: usize) -> Self {
        PrefetchDataset { inner, workers: workers.max(1), ahead: ahead.max(1) }
    }

    /// Iterate the dataset in order with background prefetching. The
    /// returned iterator owns the worker pool for its lifetime.
    pub fn iter(&self) -> PrefetchIter {
        let n = self.inner.len();
        let (task_tx, task_rx) = mpsc::channel::<usize>();
        let task_rx = Arc::new(std::sync::Mutex::new(task_rx));
        let (done_tx, done_rx) = mpsc::channel::<(usize, WorkerItem)>();
        let mut handles = Vec::new();
        for _ in 0..self.workers {
            let rx = task_rx.clone();
            let tx = done_tx.clone();
            let ds = self.inner.clone();
            handles.push(std::thread::spawn(move || {
                loop {
                    let idx = { rx.lock().unwrap().recv() };
                    match idx {
                        Ok(i) => {
                            // a panicking transform must not kill the
                            // worker (or surface as an opaque channel
                            // disconnect): catch it and ship the message
                            let sample = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| ds.get(i)),
                            )
                            .map_err(|p| panic_message(p.as_ref()));
                            if tx.send((i, sample)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                }
            }));
        }
        // seed the window
        let mut submitted = 0usize;
        while submitted < self.ahead.min(n) {
            task_tx.send(submitted).unwrap();
            submitted += 1;
        }
        PrefetchIter {
            n,
            next: 0,
            submitted,
            task_tx: Some(task_tx),
            done_rx,
            ready: HashMap::new(),
            handles,
        }
    }
}

impl Dataset for PrefetchDataset {
    fn len(&self) -> usize {
        self.inner.len()
    }
    /// Random access falls through to the inner dataset (no prefetch).
    fn get(&self, i: usize) -> Sample {
        self.inner.get(i)
    }
}

/// Ordered iterator with a live worker pool.
pub struct PrefetchIter {
    n: usize,
    next: usize,
    submitted: usize,
    task_tx: Option<mpsc::Sender<usize>>,
    done_rx: mpsc::Receiver<(usize, WorkerItem)>,
    ready: HashMap<usize, WorkerItem>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl PrefetchIter {
    /// Like [`Iterator::next`], but a worker panic comes back as a typed
    /// error naming the failed sample index and the original panic
    /// message. The pool stays alive, so calling again continues with the
    /// next sample.
    pub fn try_next(&mut self) -> Option<Result<Sample>> {
        if self.next >= self.n {
            return None;
        }
        // drain completions until the in-order sample arrives
        while !self.ready.contains_key(&self.next) {
            match self.done_rx.recv() {
                Ok((i, s)) => {
                    self.ready.insert(i, s);
                }
                Err(_) => {
                    let i = self.next;
                    self.next += 1;
                    return Some(Err(Error::msg(format!(
                        "prefetch: worker pool disconnected before sample {i} was produced"
                    ))));
                }
            }
        }
        let idx = self.next;
        let item = self.ready.remove(&idx).unwrap();
        self.next += 1;
        // keep the pipeline full even when this sample failed
        if self.submitted < self.n {
            if let Some(tx) = &self.task_tx {
                tx.send(self.submitted).ok();
                self.submitted += 1;
            }
        }
        Some(item.map_err(|cause| {
            Error::msg(format!("prefetch: worker panicked computing sample {idx}: {cause}"))
        }))
    }
}

impl Iterator for PrefetchIter {
    type Item = Sample;

    /// Panics with a labeled message (sample index + original cause) if a
    /// worker panicked; use [`PrefetchIter::try_next`] to handle the
    /// failure as a typed error instead.
    fn next(&mut self) -> Option<Sample> {
        self.try_next().map(|r| r.unwrap_or_else(|e| panic!("{e}")))
    }
}

impl Drop for PrefetchIter {
    fn drop(&mut self) {
        // closing the task channel stops the workers
        self.task_tx.take();
        // drain to unblock senders
        while self.done_rx.try_recv().is_ok() {}
        for h in self.handles.drain(..) {
            h.join().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorDataset, TransformDataset};
    use crate::tensor::{DType, Tensor};

    #[test]
    fn preserves_order_with_parallel_workers() {
        let x = Tensor::arange(64, DType::F32).reshape(&[64, 1]);
        let slow = TransformDataset::new(Arc::new(TensorDataset::new(vec![x])), |s| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            s
        });
        let pf = PrefetchDataset::new(Arc::new(slow), 4, 8);
        let got: Vec<f32> = pf.iter().map(|s| s[0].to_vec()[0]).collect();
        assert_eq!(got, (0..64).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn prefetch_overlaps_work() {
        use std::time::Instant;
        let x = Tensor::arange(32, DType::F32).reshape(&[32, 1]);
        let make = || {
            TransformDataset::new(Arc::new(TensorDataset::new(vec![x.clone()])), |s| {
                std::thread::sleep(std::time::Duration::from_millis(2));
                s
            })
        };
        let t0 = Instant::now();
        let serial: usize = (0..32).map(|i| make().get(i).len()).sum();
        let serial_time = t0.elapsed();
        let pf = PrefetchDataset::new(Arc::new(make()), 8, 16);
        let t1 = Instant::now();
        let par: usize = pf.iter().map(|s| s.len()).sum();
        let par_time = t1.elapsed();
        assert_eq!(serial, par);
        assert!(
            par_time < serial_time,
            "prefetch ({par_time:?}) not faster than serial ({serial_time:?})"
        );
    }

    #[test]
    fn drop_mid_iteration_is_clean() {
        let x = Tensor::arange(100, DType::F32).reshape(&[100, 1]);
        let pf = PrefetchDataset::new(Arc::new(TensorDataset::new(vec![x])), 2, 4);
        let mut it = pf.iter();
        let _ = it.next();
        drop(it); // must not hang or panic
    }

    /// A dataset whose transform panics on one specific sample.
    fn bomb_dataset(n: usize, bad: f32) -> PrefetchDataset {
        let x = Tensor::arange(n, DType::F32).reshape(&[n as isize, 1]);
        let bomb = TransformDataset::new(Arc::new(TensorDataset::new(vec![x])), move |s| {
            if s[0].to_vec()[0] == bad {
                panic!("augmentation exploded");
            }
            s
        });
        PrefetchDataset::new(Arc::new(bomb), 2, 4)
    }

    #[test]
    fn worker_panic_surfaces_a_labeled_error_and_pool_survives() {
        let pf = bomb_dataset(8, 3.0);
        let mut it = pf.iter();
        for i in 0..3 {
            let s = it.try_next().unwrap().unwrap();
            assert_eq!(s[0].to_vec()[0], i as f32);
        }
        let err = it.try_next().unwrap().unwrap_err().to_string();
        assert!(err.contains("sample 3"), "error must name the sample index: {err}");
        assert!(err.contains("augmentation exploded"), "error must carry the cause: {err}");
        // the pool survived the panic: the remaining samples still arrive
        // in order
        let mut rest = Vec::new();
        while let Some(r) = it.try_next() {
            rest.push(r.unwrap()[0].to_vec()[0]);
        }
        assert_eq!(rest, vec![4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn iterator_panic_is_labeled() {
        let pf = bomb_dataset(6, 2.0);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            for _ in pf.iter() {}
        }))
        .unwrap_err();
        let msg = panic_message(caught.as_ref());
        assert!(msg.contains("sample 2"), "panic must name the sample: {msg}");
        assert!(msg.contains("augmentation exploded"), "panic must carry the cause: {msg}");
    }
}
