//! Sample-level transformation dataset (augmentations, preprocessing).

use std::sync::Arc;

use super::{Dataset, Sample};

/// Applies a function to each sample on access (composes with shuffling,
/// batching, and prefetching).
pub struct TransformDataset {
    inner: Arc<dyn Dataset>,
    f: Box<dyn Fn(Sample) -> Sample + Send + Sync>,
}

impl TransformDataset {
    /// Wrap `inner` with transform `f`.
    pub fn new(inner: Arc<dyn Dataset>, f: impl Fn(Sample) -> Sample + Send + Sync + 'static) -> Self {
        TransformDataset { inner, f: Box::new(f) }
    }
}

impl Dataset for TransformDataset {
    fn len(&self) -> usize {
        self.inner.len()
    }
    fn get(&self, i: usize) -> Sample {
        (self.f)(self.inner.get(i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TensorDataset;
    use crate::tensor::{DType, Tensor};

    #[test]
    fn applies_transform_lazily() {
        let x = Tensor::arange(4, DType::F32).reshape(&[4, 1]);
        let ds = TransformDataset::new(
            Arc::new(TensorDataset::new(vec![x])),
            |mut s| {
                s[0] = s[0].mul_scalar(10.0);
                s
            },
        );
        assert_eq!(ds.get(3)[0].to_vec(), vec![30.0]);
        assert_eq!(ds.len(), 4);
    }
}
