//! Order-shuffling dataset.

use std::sync::{Arc, RwLock};

use crate::util::rng::Rng;

use super::{Dataset, Sample};

/// Presents `inner` in a (re-seedable) random order.
pub struct ShuffleDataset {
    inner: Arc<dyn Dataset>,
    perm: RwLock<Vec<usize>>,
}

impl ShuffleDataset {
    /// Shuffle with the given seed.
    pub fn new(inner: Arc<dyn Dataset>, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let perm = rng.permutation(inner.len());
        ShuffleDataset { inner, perm: RwLock::new(perm) }
    }

    /// Re-shuffle (per-epoch).
    pub fn resample(&self, seed: u64) {
        let mut rng = Rng::new(seed);
        *self.perm.write().unwrap() = rng.permutation(self.inner.len());
    }
}

impl Dataset for ShuffleDataset {
    fn len(&self) -> usize {
        self.inner.len()
    }

    fn get(&self, i: usize) -> Sample {
        let j = self.perm.read().unwrap()[i];
        self.inner.get(j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::TensorDataset;
    use crate::tensor::{DType, Tensor};

    fn values(ds: &dyn Dataset) -> Vec<f32> {
        (0..ds.len()).map(|i| ds.get(i)[0].to_vec()[0]).collect()
    }

    #[test]
    fn is_permutation_of_inner() {
        let x = Tensor::arange(20, DType::F32).reshape(&[20, 1]);
        let ds = ShuffleDataset::new(Arc::new(TensorDataset::new(vec![x])), 7);
        let mut v = values(&ds);
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(v, (0..20).map(|i| i as f32).collect::<Vec<_>>());
    }

    #[test]
    fn seeds_are_deterministic_and_resample_changes_order() {
        let x = Tensor::arange(50, DType::F32).reshape(&[50, 1]);
        let inner = Arc::new(TensorDataset::new(vec![x]));
        let a = ShuffleDataset::new(inner.clone(), 1);
        let b = ShuffleDataset::new(inner.clone(), 1);
        assert_eq!(values(&a), values(&b));
        let before = values(&a);
        a.resample(2);
        assert_ne!(values(&a), before);
    }
}
