//! Seedable pseudo-random number generation.
//!
//! No external `rand` crate is used: the reference backend only needs a
//! small, fast, reproducible generator. We use SplitMix64 for seeding and
//! xoshiro256** for the stream — both public-domain algorithms.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global seed used to derive per-thread generators.
static GLOBAL_SEED: AtomicU64 = AtomicU64::new(0x9E3779B97F4A7C15);
static STREAM_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Set the global seed. Affects generators created afterwards, and also
/// resets the *calling thread's* generator so repeated `seed(s)` calls in
/// one thread replay the same stream (training loops and the compiled-step
/// parity tests rely on this).
pub fn seed(s: u64) {
    GLOBAL_SEED.store(s, Ordering::SeqCst);
    STREAM_COUNTER.store(0, Ordering::SeqCst);
    reseed_thread(s);
}

/// Replace the calling thread's generator with a fresh one derived from
/// `s`. Unlike [`seed`], this touches no global state: other threads'
/// streams are unaffected, so concurrently running tests cannot perturb a
/// determinism check.
pub fn reseed_thread(s: u64) {
    THREAD_RNG.with(|r| *r.borrow_mut() = Rng::new(s));
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal sample from Box–Muller.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from an explicit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Create a generator derived from the global seed; each call gets a
    /// distinct stream.
    pub fn from_global() -> Self {
        let base = GLOBAL_SEED.load(Ordering::SeqCst);
        let stream = STREAM_COUNTER.fetch_add(1, Ordering::SeqCst);
        Rng::new(base ^ stream.wrapping_mul(0xA24BAED4963EE407))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

thread_local! {
    static THREAD_RNG: RefCell<Rng> = RefCell::new(Rng::from_global());
}

/// Run a closure with the thread-local generator.
pub fn with_thread_rng<T>(f: impl FnOnce(&mut Rng) -> T) -> T {
    THREAD_RNG.with(|r| f(&mut r.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(9);
        let p = r.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
