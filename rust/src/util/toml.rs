//! A minimal TOML-subset parser for the config system.
//!
//! No external `toml`/`serde` crates are available offline, and the config
//! files this framework needs are flat: `[section]` tables with string /
//! int / float / bool / string-array scalars. This parser supports exactly
//! that subset, with `#` comments and quoted strings.

use std::collections::BTreeMap;

use crate::util::error::{Error, Result};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// String value.
    Str(String),
    /// Integer value.
    Int(i64),
    /// Float value.
    Float(f64),
    /// Boolean value.
    Bool(bool),
    /// Homogeneous array of values.
    Array(Vec<Value>),
}

impl Value {
    /// Interpret as string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Interpret as integer (ints only).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// Interpret as float (accepts ints).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// Interpret as bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Interpret as array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// A parsed document: `section.key -> Value`. Keys outside any section
/// live under the empty section `""`.
#[derive(Debug, Clone, Default)]
pub struct Doc {
    /// section -> key -> value
    pub tables: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Look up `key` in `section`.
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.tables.get(section).and_then(|t| t.get(key))
    }

    /// Set a value (used for CLI overrides like `--set train.lr=0.1`).
    pub fn set(&mut self, section: &str, key: &str, v: Value) {
        self.tables.entry(section.to_string()).or_default().insert(key.to_string(), v);
    }

    /// Parse a `section.key=value` override string.
    pub fn apply_override(&mut self, spec: &str) -> Result<()> {
        let (path, raw) = spec
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("override `{spec}` missing `=`")))?;
        let (section, key) = match path.rsplit_once('.') {
            Some((s, k)) => (s.to_string(), k.to_string()),
            None => (String::new(), path.to_string()),
        };
        let v = parse_value(raw.trim())?;
        self.set(&section, &key, v);
        Ok(())
    }
}

fn parse_string(s: &str) -> Result<(String, &str)> {
    // s starts right after the opening quote
    let mut out = String::new();
    let mut chars = s.char_indices();
    while let Some((i, c)) = chars.next() {
        match c {
            '"' => return Ok((out, &s[i + 1..])),
            '\\' => match chars.next() {
                Some((_, 'n')) => out.push('\n'),
                Some((_, 't')) => out.push('\t'),
                Some((_, '"')) => out.push('"'),
                Some((_, '\\')) => out.push('\\'),
                other => {
                    return Err(Error::Config(format!("bad escape {other:?} in string")));
                }
            },
            c => out.push(c),
        }
    }
    Err(Error::Config("unterminated string".into()))
}

/// Parse one scalar or array value.
pub fn parse_value(raw: &str) -> Result<Value> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(Error::Config("empty value".into()));
    }
    if let Some(rest) = raw.strip_prefix('"') {
        let (s, tail) = parse_string(rest)?;
        if !tail.trim().is_empty() {
            return Err(Error::Config(format!("trailing characters after string: `{tail}`")));
        }
        return Ok(Value::Str(s));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| Error::Config(format!("unterminated array: `{raw}`")))?;
        let mut items = Vec::new();
        // split on top-level commas (strings may contain commas)
        let mut depth_in_string = false;
        let mut start = 0usize;
        let bytes = inner.as_bytes();
        for (i, &b) in bytes.iter().enumerate() {
            match b {
                b'"' if i == 0 || bytes[i - 1] != b'\\' => depth_in_string = !depth_in_string,
                b',' if !depth_in_string => {
                    let piece = inner[start..i].trim();
                    if !piece.is_empty() {
                        items.push(parse_value(piece)?);
                    }
                    start = i + 1;
                }
                _ => {}
            }
        }
        let piece = inner[start..].trim();
        if !piece.is_empty() {
            items.push(parse_value(piece)?);
        }
        return Ok(Value::Array(items));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    // bare word — treat as string (lenient, convenient for CLI overrides)
    Ok(Value::Str(raw.to_string()))
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = strip_comment(line).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {}: bad table header", lineno + 1)))?;
            section = name.trim().to_string();
            doc.tables.entry(section.clone()).or_default();
            continue;
        }
        let (key, raw) = line
            .split_once('=')
            .ok_or_else(|| Error::Config(format!("line {}: expected `key = value`", lineno + 1)))?;
        let v = parse_value(raw)
            .map_err(|e| Error::Config(format!("line {}: {e}", lineno + 1)))?;
        doc.set(&section, key.trim(), v);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string
    let mut in_str = false;
    let bytes = line.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        match b {
            b'"' if i == 0 || bytes[i - 1] != b'\\' => in_str = !in_str,
            b'#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = parse(
            r#"
            top = 1
            [train]            # trainer settings
            lr = 0.1
            steps = 300
            name = "bert-tiny"
            amp = false
            tags = ["a", "b,c", 3]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("", "top").unwrap().as_int(), Some(1));
        assert_eq!(doc.get("train", "lr").unwrap().as_float(), Some(0.1));
        assert_eq!(doc.get("train", "steps").unwrap().as_int(), Some(300));
        assert_eq!(doc.get("train", "name").unwrap().as_str(), Some("bert-tiny"));
        assert_eq!(doc.get("train", "amp").unwrap().as_bool(), Some(false));
        let arr = doc.get("train", "tags").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_str(), Some("b,c"));
        assert_eq!(arr[2].as_int(), Some(3));
    }

    #[test]
    fn int_coerces_to_float() {
        let v = parse_value("5").unwrap();
        assert_eq!(v.as_float(), Some(5.0));
    }

    #[test]
    fn overrides() {
        let mut doc = Doc::default();
        doc.apply_override("train.lr=0.5").unwrap();
        doc.apply_override("model.name=vit").unwrap();
        doc.apply_override("seed=42").unwrap();
        assert_eq!(doc.get("train", "lr").unwrap().as_float(), Some(0.5));
        assert_eq!(doc.get("model", "name").unwrap().as_str(), Some("vit"));
        assert_eq!(doc.get("", "seed").unwrap().as_int(), Some(42));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[oops").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse_value("\"unterminated").is_err());
    }

    #[test]
    fn comment_stripping_respects_strings() {
        let doc = parse("k = \"a # not comment\" # real comment").unwrap();
        assert_eq!(doc.get("", "k").unwrap().as_str(), Some("a # not comment"));
    }
}
