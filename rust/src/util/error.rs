//! Library error type. Kept deliberately small: the paper's library favors
//! explicit, unopinionated interfaces over deep error taxonomies.
//!
//! No external error-derive crate is used (the build is offline and
//! dependency-free); `Display`, `std::error::Error`, and the `io::Error`
//! conversion are implemented by hand.

/// Errors produced by flashlight-rs.
#[derive(Debug)]
pub enum Error {
    /// Two shapes that were required to match (or broadcast) did not.
    ShapeMismatch(String),
    /// An operation was invoked with an unsupported dtype.
    DType(String),
    /// An index / axis was out of range.
    Index(String),
    /// A backend does not implement the requested operation.
    Unsupported {
        /// Name of the backend that rejected the op.
        backend: String,
        /// The rejected operation.
        op: String,
    },
    /// Memory-manager failure.
    Memory(String),
    /// Distributed-runtime failure.
    Distributed(String),
    /// Serialization / checkpoint failure.
    Serde(String),
    /// Configuration / CLI error.
    Config(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Static graph verification failure (see `tensor::graph::verify`):
    /// the joined diagnostics, each carrying kind / op / pass provenance.
    Verify(String),
    /// I/O error.
    Io(std::io::Error),
    /// Anything else.
    Msg(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::ShapeMismatch(m) => write!(f, "shape mismatch: {m}"),
            Error::DType(m) => write!(f, "dtype error: {m}"),
            Error::Index(m) => write!(f, "index error: {m}"),
            Error::Unsupported { backend, op } => {
                write!(f, "backend `{backend}` does not support {op}")
            }
            Error::Memory(m) => write!(f, "memory error: {m}"),
            Error::Distributed(m) => write!(f, "distributed error: {m}"),
            Error::Serde(m) => write!(f, "serialization error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Verify(m) => write!(f, "graph verification failed: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Msg(m) => f.write_str(m),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand for a free-form error message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Unsupported { backend: "lazy".into(), op: "conv2d".into() };
        assert_eq!(e.to_string(), "backend `lazy` does not support conv2d");
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
    }

    #[test]
    fn io_error_is_transparent_and_sourced() {
        use std::error::Error as _;
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let text = io.to_string();
        let e: Error = io.into();
        assert_eq!(e.to_string(), text);
        assert!(e.source().is_some());
        assert!(Error::msg("x").source().is_none());
    }
}
