//! Library error type. Kept deliberately small: the paper's library favors
//! explicit, unopinionated interfaces over deep error taxonomies.

use thiserror::Error;

/// Errors produced by flashlight-rs.
#[derive(Error, Debug)]
pub enum Error {
    /// Two shapes that were required to match (or broadcast) did not.
    #[error("shape mismatch: {0}")]
    ShapeMismatch(String),
    /// An operation was invoked with an unsupported dtype.
    #[error("dtype error: {0}")]
    DType(String),
    /// An index / axis was out of range.
    #[error("index error: {0}")]
    Index(String),
    /// A backend does not implement the requested operation.
    #[error("backend `{backend}` does not support {op}")]
    Unsupported { backend: String, op: String },
    /// Memory-manager failure.
    #[error("memory error: {0}")]
    Memory(String),
    /// Distributed-runtime failure.
    #[error("distributed error: {0}")]
    Distributed(String),
    /// Serialization / checkpoint failure.
    #[error("serialization error: {0}")]
    Serde(String),
    /// Configuration / CLI error.
    #[error("config error: {0}")]
    Config(String),
    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),
    /// I/O error.
    #[error(transparent)]
    Io(#[from] std::io::Error),
    /// Anything else.
    #[error("{0}")]
    Msg(String),
}

/// Library result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand for a free-form error message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error::Msg(m.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        let e = Error::Unsupported { backend: "lazy".into(), op: "conv2d".into() };
        assert_eq!(e.to_string(), "backend `lazy` does not support conv2d");
        let e = Error::msg("boom");
        assert_eq!(e.to_string(), "boom");
    }
}
