//! Small shared utilities: errors, RNG, parallel-for, timing.

pub mod error;
pub mod parallel;
pub mod rng;
pub mod timing;
pub mod toml;

pub use error::{Error, Result};
pub use rng::Rng;
