//! Timing helpers shared by benches and the trainer.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed time since start.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Elapsed seconds as f64.
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart, returning the previous elapsed duration.
    pub fn lap(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Summary statistics over repeated measurements (used by the bench
/// harness; criterion is unavailable offline).
#[derive(Debug, Clone)]
pub struct Samples {
    /// Raw measurements in seconds.
    pub secs: Vec<f64>,
}

impl Samples {
    /// Collect `n` timed runs of `f`, after `warmup` untimed runs.
    pub fn collect(warmup: usize, n: usize, mut f: impl FnMut()) -> Self {
        for _ in 0..warmup {
            f();
        }
        let mut secs = Vec::with_capacity(n);
        for _ in 0..n {
            let t = Timer::start();
            f();
            secs.push(t.secs());
        }
        Samples { secs }
    }

    /// Mean of the samples.
    pub fn mean(&self) -> f64 {
        if self.secs.is_empty() {
            return 0.0;
        }
        self.secs.iter().sum::<f64>() / self.secs.len() as f64
    }

    /// Median of the samples.
    pub fn median(&self) -> f64 {
        if self.secs.is_empty() {
            return 0.0;
        }
        let mut v = self.secs.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = v.len();
        if n % 2 == 1 {
            v[n / 2]
        } else {
            0.5 * (v[n / 2 - 1] + v[n / 2])
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.secs.len() < 2 {
            return 0.0;
        }
        (self.secs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / self.secs.len() as f64).sqrt()
    }

    /// Minimum sample.
    pub fn min(&self) -> f64 {
        self.secs.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// Total across samples.
    pub fn total(&self) -> f64 {
        self.secs.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let s = Samples { secs: vec![1.0, 2.0, 3.0, 4.0] };
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.median() - 2.5).abs() < 1e-12);
        assert!((s.min() - 1.0).abs() < 1e-12);
        assert!((s.total() - 10.0).abs() < 1e-12);
        let s = Samples { secs: vec![3.0, 1.0, 2.0] };
        assert!((s.median() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn collect_counts_runs() {
        let mut calls = 0;
        let s = Samples::collect(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.secs.len(), 5);
    }
}
