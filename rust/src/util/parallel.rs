//! Scoped data-parallel helpers over native threads.
//!
//! The reference backend parallelizes its hot loops (GEMM, conv, large
//! elementwise maps) with plain `std::thread::scope` — no external runtime.
//! This mirrors the paper's "native C++ threads" approach for dataset
//! parallelism and keeps the dependency surface minimal.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use for data-parallel loops.
///
/// Defaults to the number of available cores, overridable with
/// `FL_NUM_THREADS`. Capped at 16: beyond that, memory bandwidth dominates
/// for the kernel sizes this library targets.
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("FL_NUM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
        .clamp(1, 16);
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Minimum per-item work (in "element" units) below which we stay serial.
pub const PAR_THRESHOLD: usize = 16 * 1024;

/// Run `f(range)` over disjoint chunks of `0..n` across worker threads.
///
/// `f` receives `(start, end)` index pairs. Falls back to a single serial
/// call when the problem is small or only one thread is configured.
pub fn parallel_chunks(n: usize, min_serial: usize, f: impl Fn(usize, usize) + Sync) {
    let threads = num_threads();
    if threads <= 1 || n <= min_serial {
        f(0, n);
        return;
    }
    let chunks = threads.min(n.max(1));
    let per = n.div_ceil(chunks);
    std::thread::scope(|s| {
        for c in 0..chunks {
            let lo = c * per;
            let hi = ((c + 1) * per).min(n);
            if lo >= hi {
                break;
            }
            let f = &f;
            s.spawn(move || f(lo, hi));
        }
    });
}

/// Split a mutable slice into per-thread chunks and run `f(chunk_index_base,
/// chunk)` on each in parallel. Used for filling output buffers.
pub fn parallel_fill<T: Send>(out: &mut [T], min_serial: usize, f: impl Fn(usize, &mut [T]) + Sync) {
    let n = out.len();
    let threads = num_threads();
    if threads <= 1 || n <= min_serial {
        f(0, out);
        return;
    }
    let per = n.div_ceil(threads);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            let b = base;
            s.spawn(move || f(b, head));
            rest = tail;
            base += take;
        }
    });
}

/// Like [`parallel_fill`], but each thread's chunk length is rounded up
/// to a multiple of `align` (except the tail), so fixed-size inner blocks
/// never straddle a thread boundary. Used by the blockwise fused-kernel
/// engine to keep every block but the last one full-width.
pub fn parallel_fill_aligned<T: Send>(
    out: &mut [T],
    min_serial: usize,
    align: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    let n = out.len();
    let threads = num_threads();
    if threads <= 1 || n <= min_serial {
        f(0, out);
        return;
    }
    let align = align.max(1);
    let per = n.div_ceil(threads).div_ceil(align) * align;
    std::thread::scope(|s| {
        let mut rest = out;
        let mut base = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = rest.split_at_mut(take);
            let f = &f;
            let b = base;
            s.spawn(move || f(b, head));
            rest = tail;
            base += take;
        }
    });
}

/// Map `0..n` to a `Vec<R>` in parallel, preserving order.
pub fn parallel_map<R: Send + Default + Clone>(
    n: usize,
    min_serial: usize,
    f: impl Fn(usize) -> R + Sync,
) -> Vec<R> {
    let mut out = vec![R::default(); n];
    parallel_fill(&mut out, min_serial, |base, chunk| {
        for (i, slot) in chunk.iter_mut().enumerate() {
            *slot = f(base + i);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let total = AtomicU64::new(0);
        let n = 100_001;
        parallel_chunks(n, 0, |lo, hi| {
            let mut s = 0u64;
            for i in lo..hi {
                s += i as u64;
            }
            total.fetch_add(s, Ordering::Relaxed);
        });
        let expect = (n as u64 - 1) * n as u64 / 2;
        assert_eq!(total.load(Ordering::Relaxed), expect);
    }

    #[test]
    fn fill_writes_every_slot() {
        let mut v = vec![0usize; 50_000];
        parallel_fill(&mut v, 0, |base, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = base + i;
            }
        });
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn aligned_fill_writes_every_slot_on_aligned_boundaries() {
        for n in [1usize, 7, 256, 50_000, 50_001] {
            let mut v = vec![0usize; n];
            let bases = std::sync::Mutex::new(Vec::new());
            parallel_fill_aligned(&mut v, 0, 256, |base, chunk| {
                bases.lock().unwrap().push(base);
                for (i, x) in chunk.iter_mut().enumerate() {
                    *x = base + i;
                }
            });
            for (i, x) in v.iter().enumerate() {
                assert_eq!(*x, i, "n={n}");
            }
            for b in bases.into_inner().unwrap() {
                assert_eq!(b % 256, 0, "chunk base must be block-aligned (n={n})");
            }
        }
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(10_000, 0, |i| i * 2);
        assert_eq!(v[777], 1554);
        assert_eq!(v.len(), 10_000);
    }

    #[test]
    fn serial_small_input() {
        // under threshold everything still works
        let v = parallel_map(3, PAR_THRESHOLD, |i| i + 1);
        assert_eq!(v, vec![1, 2, 3]);
    }
}
