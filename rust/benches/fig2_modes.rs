//! Figure 2 reproduction: the same Tensor program executed under three
//! computation modes behind the one backend API — eager (CPU), deferred
//! with fusion (lazy), and AOT/static (XLA artifacts via PJRT) — with
//! identical numerics, plus a buffer-allocation comparison showing the
//! deferred mode's fusion eliminating intermediate materialization.
//!
//! Run: `cargo bench --bench fig2_modes`

use std::sync::Arc;

use flashlight::memory::{self, DefaultMemoryManager, TelemetryMemoryManager};
use flashlight::tensor::lazy::LazyBackend;
use flashlight::tensor::xla_backend::XlaBackend;
use flashlight::tensor::{BackendGuard, Tensor};
use flashlight::util::timing::Samples;

/// The probe program: matmul into a chain of element-wise ops.
fn program(a: &Tensor, b: &Tensor) -> Vec<f32> {
    a.matmul(b).add(b).tanh().mul(a).sub(b).abs().to_vec()
}

fn count_allocs(f: impl Fn()) -> u64 {
    let telemetry = Arc::new(TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new())));
    let prev = memory::install(telemetry.clone());
    f();
    if let Some(p) = prev {
        memory::install(p);
    }
    telemetry.trace().iter().filter(|e| e.kind == memory::EventKind::Alloc).count() as u64
}

fn main() {
    flashlight::util::rng::seed(42);
    let n = 256;
    let av = Tensor::rand([n, n], -1.0, 1.0).to_vec();
    let bv = Tensor::rand([n, n], -1.0, 1.0).to_vec();
    let make = || {
        (Tensor::from_slice(&av, [n, n]), Tensor::from_slice(&bv, [n, n]))
    };

    // eager
    let (a, b) = make();
    let eager_out = program(&a, &b);
    let eager_time = Samples::collect(2, 5, || {
        let _ = program(&a, &b);
    });
    let eager_allocs = count_allocs(|| {
        let (a, b) = make();
        let _ = program(&a, &b);
    });

    // deferred + fused
    let _guard = BackendGuard::install(LazyBackend::shared());
    let (a, b) = make();
    let lazy_out = program(&a, &b);
    let lazy_time = Samples::collect(2, 5, || {
        let _ = program(&a, &b);
    });
    let lazy_allocs = count_allocs(|| {
        let (a, b) = make();
        let _ = program(&a, &b);
    });
    drop(_guard);

    println!("== Figure 2: computation modes behind one backend API ==");
    println!("{:<18} {:>12} {:>14} {:>10}", "MODE", "median (ms)", "buffer allocs", "matches");
    let diff = eager_out
        .iter()
        .zip(&lazy_out)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!(
        "{:<18} {:>12.2} {:>14} {:>10}",
        "eager (cpu)",
        eager_time.median() * 1e3,
        eager_allocs,
        "ref"
    );
    println!(
        "{:<18} {:>12.2} {:>14} {:>10}",
        "deferred (lazy)",
        lazy_time.median() * 1e3,
        lazy_allocs,
        format!("{diff:.1e}")
    );
    assert!(diff < 1e-3, "lazy mode numerics diverged: {diff}");
    assert!(
        lazy_allocs < eager_allocs,
        "fusion should reduce intermediate buffers: {lazy_allocs} vs {eager_allocs}"
    );

    // static/AOT mode (artifact shapes: 32x256 @ 256x256)
    match XlaBackend::from_global_runtime() {
        Some(xla) => {
            let x = Tensor::rand([32, 256], -1.0, 1.0);
            let w = Tensor::rand([256, 256], -1.0, 1.0);
            let want = x.matmul(&w);
            let _guard = BackendGuard::install(xla.clone());
            let x2 = Tensor::from_slice(&x.to_vec(), [32, 256]);
            let w2 = Tensor::from_slice(&w.to_vec(), [256, 256]);
            let got = x2.matmul(&w2);
            let t = Samples::collect(2, 5, || {
                let _ = x2.matmul(&w2).to_vec();
            });
            let d = got.max_abs_diff(&want).unwrap();
            println!(
                "{:<18} {:>12.2} {:>14} {:>10}",
                "static (xla-aot)",
                t.median() * 1e3,
                "-",
                format!("{d:.1e}")
            );
            assert!(d < 1e-3);
            let (off, _) = xla.counts();
            println!("xla offloads executed: {off}");
        }
        None => println!("static (xla-aot)  skipped: run `make artifacts`"),
    }
    println!("fig2_modes OK — identical numerics across computation modes");
}
