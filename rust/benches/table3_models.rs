//! Table 3 reproduction: seconds per 100 iterations of data loading +
//! forward + backward (+ data-parallel gradient sync for the multi-worker
//! column) on the six benchmark models.
//!
//! Columns: `FL` = this framework's reference CPU backend; `baseline` =
//! the bloat backend modelling large-framework per-op overhead (DESIGN.md
//! substitution for the PyTorch/TF rows — identical kernels, added
//! dispatch cost). Shape claims under test: FL <= baseline everywhere, the
//! gap is largest for low-arithmetic-intensity models (AlexNet-class), and
//! the multi-worker run adds only modest overhead per step.
//!
//! Run: `cargo bench --bench table3_models [iters] [workers]` (paper: 100 8)

use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::baseline::BloatBackend;
use flashlight::coordinator::TrainConfig;
use flashlight::data::{BatchDataset, Dataset};
use flashlight::dist::{init_ring, DistributedInterface, GradientSynchronizer};
use flashlight::models::{by_name, TABLE3_MODELS};
use flashlight::nn::{categorical_cross_entropy, Module};
use flashlight::tensor::{BackendGuard, DType, Tensor};
use flashlight::util::timing::Timer;

fn make_batch(spec: &flashlight::models::ModelSpec) -> (Tensor, Tensor) {
    match spec.image_input {
        Some((c, h, w)) => (
            Tensor::rand([spec.batch, c, h, w], -1.0, 1.0),
            Tensor::rand([spec.batch], 0.0, spec.classes as f64).astype(DType::I64),
        ),
        None => (
            Tensor::rand([spec.batch, spec.seq_len], 0.0, spec.vocab as f64).astype(DType::I64),
            Tensor::rand([spec.batch * spec.seq_len], 0.0, spec.classes as f64)
                .astype(DType::I64),
        ),
    }
}

/// One training iteration: synth data load + forward + loss + backward.
fn iteration(model: &dyn Module, spec: &flashlight::models::ModelSpec) {
    let (x, y) = make_batch(spec); // data loading included, per the paper
    let out = model.forward(&Variable::constant(x));
    let (logits, y) = if out.dims().len() == 3 {
        // sequence logits [B, T, C]: frame-level targets
        let d: Vec<usize> = out.dims();
        let flat =
            flashlight::autograd::ops::reshape(&out, &[(d[0] * d[1]) as isize, d[2] as isize]);
        let yt = Tensor::rand([d[0] * d[1]], 0.0, d[2] as f64).astype(DType::I64);
        (flat, yt)
    } else {
        (out, y)
    };
    let loss = categorical_cross_entropy(&logits, &y);
    loss.backward();
}

fn bench_single(name: &str, iters: usize) -> (f64, f64) {
    // FL reference backend
    let (mut model, spec) = by_name(name).unwrap();
    model.set_train(true);
    for _ in 0..iters.min(3) {
        iteration(model.as_ref(), &spec); // warmup
    }
    let t = Timer::start();
    for _ in 0..iters {
        iteration(model.as_ref(), &spec);
    }
    let fl = t.secs();

    // bloat baseline backend — same kernels, large-framework overhead
    let _guard = BackendGuard::install(BloatBackend::over_cpu_default());
    let (mut model_b, spec_b) = by_name(name).unwrap();
    model_b.set_train(true);
    for _ in 0..iters.min(3) {
        iteration(model_b.as_ref(), &spec_b);
    }
    let t = Timer::start();
    for _ in 0..iters {
        iteration(model_b.as_ref(), &spec_b);
    }
    (fl, t.secs())
}

fn bench_workers(name: &str, iters: usize, workers: usize) -> f64 {
    let ring = init_ring(workers);
    let t = Timer::start();
    std::thread::scope(|s| {
        for w in ring {
            s.spawn(move || {
                let (mut model, spec) = by_name(name).unwrap();
                model.set_train(true);
                let dist: Arc<dyn DistributedInterface + Sync> = Arc::new(w);
                let sync = GradientSynchronizer::new(dist);
                for _ in 0..iters {
                    iteration(model.as_ref(), &spec);
                    sync.synchronize(&model.params());
                    for p in model.params() {
                        p.zero_grad();
                    }
                }
            });
        }
    });
    t.secs()
}

fn main() {
    // paper protocol is 100 iterations; default 20 keeps `cargo bench`
    // wall-clock sane on the single-core testbed (pass 100 to match)
    let iters: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(20);
    let workers: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let _ = TrainConfig::default(); // exercise the config path in benches
    let _ = BatchDataset::new(
        Arc::new(flashlight::data::TensorDataset::new(vec![Tensor::zeros([4, 1])])),
        2,
    )
    .len();

    println!("== Table 3: seconds per {iters} iterations (fwd+bwd+data) ==");
    println!(
        "{:<10} {:>8} {:>12} {:>12} {:>9} {:>14}",
        "MODEL", "params", "FL (1w)", "baseline", "ratio", format!("FL ({workers}w)")
    );
    let mut rows = Vec::new();
    for name in TABLE3_MODELS {
        let (model, _) = by_name(name).unwrap();
        let params = flashlight::nn::num_params(model.as_ref());
        drop(model);
        let (fl, baseline) = bench_single(name, iters);
        let dist = bench_workers(name, iters.div_ceil(4), workers) * 4.0; // scaled estimate
        let ratio = baseline / fl;
        println!(
            "{:<10} {:>7}k {:>11.2}s {:>11.2}s {:>8.2}x {:>13.2}s",
            name,
            params / 1000,
            fl,
            baseline,
            ratio,
            dist
        );
        rows.push((name, fl, baseline, ratio));
    }

    // paper-shape assertions. On this CPU testbed, kernel time dwarfs
    // per-op dispatch for batched models (a V100 with cuDNN kernels makes
    // overhead proportionally larger), so the end-to-end rows only assert
    // a no-regression band; the small-op probe below shows the overhead
    // gap unambiguously.
    for (name, fl, baseline, _) in &rows {
        assert!(
            *baseline >= fl * 0.85,
            "{name}: baseline ({baseline:.3}s) implausibly faster than FL ({fl:.3}s)"
        );
    }
    println!("\nshape check: baseline never materially beats FL ✔");

    // framework-overhead probe: tiny tensors, many ops — where the paper's
    // AlexNet-vs-VGG gap comes from
    let probe = |label: &str| -> f64 {
        let x = Tensor::rand([16], -1.0, 1.0);
        let t = Timer::start();
        for _ in 0..4000 {
            std::hint::black_box(x.add(&x).mul(&x).relu());
        }
        let secs = t.secs();
        println!("  {label:<18} {:.3}s / 12k small ops", secs);
        secs
    };
    println!("\nsmall-op overhead probe (12k element-wise ops on 16-elem tensors):");
    let fl_small = probe("FL (cpu)");
    let guard = BackendGuard::install(BloatBackend::over_cpu_default());
    let bl_small = probe("baseline (bloat)");
    drop(guard);
    println!(
        "  overhead ratio: {:.2}x (paper: large-framework overhead dominates \
         low-arithmetic-intensity work)",
        bl_small / fl_small
    );
    assert!(bl_small > fl_small, "bloat baseline must be slower on tiny ops");
}
