//! §5.2.2 case-study bench: caching-allocator fragmentation under real
//! training traces, ablating the split-restriction knob (the paper's
//! researchers reduced fragmentation "by over 20%" by restricting
//! splitting of large cache blocks).
//!
//! Captures op-attributed allocation traces from live transformer and CNN
//! training via the telemetry manager, then replays each identical trace
//! through caching-allocator configurations and reports peak fragmentation
//! and allocator hit rates.
//!
//! Run: `cargo bench --bench case_memory`

use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::memory::{
    self, AllocEvent, CachingMemoryManager, DefaultMemoryManager, MemoryManagerAdapter,
    TelemetryMemoryManager,
};
use flashlight::models::{alexnet, BertLike};
use flashlight::nn::{categorical_cross_entropy, Module};
use flashlight::optim::{AdamOptimizer, Optimizer};
use flashlight::tensor::{DType, Tensor};

fn capture(steps: usize, mut step: impl FnMut()) -> Vec<AllocEvent> {
    let tm = Arc::new(TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new())));
    let prev = memory::install(tm.clone());
    for _ in 0..steps {
        step();
    }
    if let Some(p) = prev {
        memory::install(p);
    }
    tm.trace()
}

struct Row {
    config: String,
    peak_frag: f64,
    peak_reserved_mb: f64,
    hit_rate: f64,
    native: u64,
}

fn replay(trace: &[AllocEvent], mgr: CachingMemoryManager) -> Row {
    let config = mgr.name().to_string();
    let (stats, peak) = memory::telemetry::replay(trace, &mgr);
    Row {
        config,
        peak_frag: peak * 100.0,
        peak_reserved_mb: stats.peak_reserved_bytes as f64 / (1 << 20) as f64,
        hit_rate: stats.cache_hit_count as f64 / stats.alloc_count.max(1) as f64 * 100.0,
        native: stats.native_alloc_count,
    }
}

fn report(label: &str, trace: &[AllocEvent]) -> (f64, f64) {
    println!("\n-- {label}: {} allocator events --", trace.len());
    println!(
        "{:<26} {:>10} {:>13} {:>9} {:>8}",
        "ALLOCATOR", "peak frag", "peak reserved", "hit rate", "native"
    );
    let rows = vec![
        replay(trace, CachingMemoryManager::unrestricted()),
        replay(trace, CachingMemoryManager::split_restricted(4 << 20)),
        replay(trace, CachingMemoryManager::split_restricted(1 << 20)),
        replay(trace, CachingMemoryManager::split_restricted(256 << 10)),
    ];
    for r in &rows {
        println!(
            "{:<26} {:>9.1}% {:>10.1} MB {:>8.1}% {:>8}",
            r.config, r.peak_frag, r.peak_reserved_mb, r.hit_rate, r.native
        );
    }
    let base = rows[0].peak_frag;
    let best = rows[1..].iter().map(|r| r.peak_frag).fold(f64::INFINITY, f64::min);
    (base, best)
}

/// Synthetic large-activation churn modeled after big-model training (the
/// paper's case study ran GPU-scale models; our CPU-scaled models only
/// allocate megabytes, so the large-pool behavior the restriction targets
/// is exercised with a trace shaped like large-model activations: varied
/// 8–64 MiB buffers allocated per step, most freed, some retained).
fn large_activation_trace(steps: usize) -> Vec<AllocEvent> {
    use flashlight::util::rng::Rng;
    let mut rng = Rng::new(42);
    let mut events = Vec::new();
    let mut id = 0u64;
    let mut retained: Vec<u64> = Vec::new();
    for _ in 0..steps {
        let mut step_ids = Vec::new();
        for _ in 0..6 {
            let mb = 8 + rng.below(56);
            events.push(AllocEvent {
                kind: memory::EventKind::Alloc,
                bytes: mb << 20,
                id,
                op: "activation",
            });
            step_ids.push(id);
            id += 1;
        }
        // free everything from this step except one retained buffer
        let keep = step_ids[rng.below(step_ids.len())];
        for s in step_ids {
            if s != keep {
                events.push(AllocEvent { kind: memory::EventKind::Free, bytes: 0, id: s, op: "activation" });
            } else {
                retained.push(s);
            }
        }
        // occasionally drop an old retained buffer
        if retained.len() > 3 {
            let victim = retained.remove(0);
            events.push(AllocEvent { kind: memory::EventKind::Free, bytes: 0, id: victim, op: "activation" });
        }
    }
    events
}

fn main() {
    flashlight::util::rng::seed(3);

    // transformer training trace
    let bert = BertLike::new(300, 64, 4, 2, 25);
    let ids = Tensor::rand([4, 25], 0.0, 300.0).astype(DType::I64);
    let mut opt = AdamOptimizer::new(bert.params(), 1e-3);
    let t_bert = capture(3, || {
        let loss = flashlight::models::bert::lm_loss(&bert, &ids);
        loss.backward();
        opt.step();
        opt.zero_grad();
    });

    // CNN training trace
    let cnn = alexnet(10);
    let x = Tensor::rand([4, 3, 32, 32], -1.0, 1.0);
    let y = Tensor::rand([4], 0.0, 10.0).astype(DType::I64);
    let mut copt = AdamOptimizer::new(cnn.params(), 1e-3);
    let t_cnn = capture(2, || {
        let out = cnn.forward(&Variable::constant(x.clone()));
        let loss = categorical_cross_entropy(&out, &y);
        loss.backward();
        copt.step();
        copt.zero_grad();
    });

    let t_large = large_activation_trace(40);

    println!("== §5.2.2: allocator fragmentation under training traces ==");
    let (b1, r1) = report("bert-like training", &t_bert);
    let (b2, r2) = report("alexnet training", &t_cnn);
    let (b3, r3) = report("large-activation churn (GPU-scale shape)", &t_large);

    let reduction = (b3 - r3) / b3.max(1e-9) * 100.0;
    println!(
        "\nlarge-model trace: best split-restriction reduces peak fragmentation \
         {b3:.1}% -> {r3:.1}% ({reduction:.0}% relative; paper: >20% for most models)"
    );
    println!(
        "scaled-model traces fit in the small pool (restriction inert): \
         bert {b1:.1}%->{r1:.1}%, alexnet {b2:.1}%->{r2:.1}%"
    );
    assert!(
        r1 <= b1 + 1e-9 && r2 <= b2 + 1e-9 && r3 <= b3 + 1e-9,
        "best split restriction should not worsen peak fragmentation"
    );
    assert!(reduction > 10.0, "restriction should help the large-pool trace ({reduction:.0}%)");
    println!("case_memory OK");
}
