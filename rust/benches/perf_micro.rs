//! L3 hot-path microbenchmarks for the performance pass (EXPERIMENTS.md
//! §Perf): GEMM GFLOP/s vs problem size, conv2d, large element-wise maps,
//! allocator throughput, ring all-reduce bandwidth, and autograd per-node
//! overhead.
//!
//! Besides the human-readable report, the run writes a machine-readable
//! `BENCH_PR2.json` at the repo root
//! (`[{"op", "ns_per_iter", "backend"}, ...]`), replacing any previous
//! run's file; the perf trajectory accumulates across PRs via version
//! control, one snapshot per PR.
//!
//! Run: `cargo bench --bench perf_micro`

use std::sync::Arc;

use flashlight::autograd::{ops, Variable};
use flashlight::memory::{CachingMemoryManager, MemoryManagerAdapter};
use flashlight::tensor::{Conv2dParams, Tensor};
use flashlight::util::timing::Samples;

/// One machine-readable measurement row.
struct Record {
    op: String,
    ns_per_iter: f64,
    backend: &'static str,
}

/// Hand-rolled JSON (the crate is dependency-free; no serde offline).
fn write_bench_json(records: &[Record]) {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_PR2.json");
    let mut s = String::from("[\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "  {{\"op\": \"{}\", \"ns_per_iter\": {:.1}, \"backend\": \"{}\"}}{}\n",
            r.op,
            r.ns_per_iter,
            r.backend,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("]\n");
    match std::fs::write(path, s) {
        Ok(()) => println!("\nwrote {} records to {path}", records.len()),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn gemm_bench(n: usize) -> f64 {
    let a = Tensor::rand([n, n], -1.0, 1.0);
    let b = Tensor::rand([n, n], -1.0, 1.0);
    let s = Samples::collect(2, 5, || {
        std::hint::black_box(a.matmul(&b));
    });
    s.median()
}

fn main() {
    let mut records: Vec<Record> = Vec::new();
    println!("== perf_micro: L3 hot paths ==");
    println!("threads: {}", flashlight::util::parallel::num_threads());

    println!("\n-- GEMM (f32) --");
    for n in [64usize, 128, 256, 512] {
        let secs = gemm_bench(n);
        let gflops = 2.0 * (n as f64).powi(3) / secs / 1e9;
        println!("  {n:>4}x{n:<4}  {gflops:>7.2} GFLOP/s");
        records.push(Record {
            op: format!("matmul_{n}x{n}"),
            ns_per_iter: secs * 1e9,
            backend: "cpu",
        });
    }

    println!("\n-- conv2d (im2col+GEMM) --");
    let x = Tensor::rand([8, 16, 32, 32], -1.0, 1.0);
    let w = Tensor::rand([32, 16, 3, 3], -0.1, 0.1);
    let p = Conv2dParams { stride: (1, 1), padding: (1, 1) };
    let s = Samples::collect(2, 5, || {
        std::hint::black_box(x.conv2d(&w, p));
    });
    let flops = 2.0 * 8.0 * 32.0 * 32.0 * 32.0 * 16.0 * 9.0;
    println!("  8x16x32x32 ⋆ 32x16x3x3: {:.2} ms ({:.2} GFLOP/s)", s.median() * 1e3, flops / s.median() / 1e9);
    records.push(Record {
        op: "conv2d_8x16x32x32_k3".into(),
        ns_per_iter: s.median() * 1e9,
        backend: "cpu",
    });

    println!("\n-- element-wise (gelu over 4M f32) --");
    let big = Tensor::rand([4 * 1024 * 1024], -2.0, 2.0);
    let s = Samples::collect(1, 5, || {
        std::hint::black_box(big.gelu());
    });
    println!("  {:.2} ms  ({:.2} GB/s effective)", s.median() * 1e3, 8.0 * 4.0 * 1048576.0 / s.median() / 1e9);
    records.push(Record {
        op: "gelu_4m".into(),
        ns_per_iter: s.median() * 1e9,
        backend: "cpu",
    });

    println!("\n-- allocator (caching manager, 64KiB blocks) --");
    let mgr = CachingMemoryManager::unrestricted();
    let s = Samples::collect(1, 5, || {
        let mut live = Vec::new();
        for _ in 0..1000 {
            live.push(mgr.alloc(64 * 1024).unwrap());
        }
        for b in live {
            mgr.unlock(b);
        }
    });
    println!("  {:.1} ns per alloc/free pair", s.median() / 1000.0 * 1e9);
    records.push(Record {
        op: "alloc_free_64k".into(),
        ns_per_iter: s.median() / 1000.0 * 1e9,
        backend: "caching-mem",
    });

    println!("\n-- ring all-reduce (4 workers, 1M f32) --");
    let s = Samples::collect(1, 3, || {
        let workers = flashlight::dist::init_ring(4);
        std::thread::scope(|sc| {
            for w in workers {
                sc.spawn(move || {
                    use flashlight::dist::DistributedInterface;
                    let t = Tensor::zeros([1 << 20]);
                    std::hint::black_box(w.all_reduce(&t, 1.0));
                });
            }
        });
    });
    println!("  {:.2} ms ({:.2} GB/s algorithmic)", s.median() * 1e3, 4.0 * 4.0 * (1 << 20) as f64 / s.median() / 1e9);
    records.push(Record {
        op: "all_reduce_ring4_1m".into(),
        ns_per_iter: s.median() * 1e9,
        backend: "dist-ring",
    });

    println!("\n-- autograd overhead (scalar chain, 10k nodes) --");
    let s = Samples::collect(1, 5, || {
        let x = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        let mut y = x.clone();
        for _ in 0..10_000 {
            y = ops::add_scalar(&y, 1.0);
        }
        y.backward();
    });
    println!("  {:.2} µs per node (fwd+bwd)", s.median() / 10_000.0 * 1e6);
    records.push(Record {
        op: "autograd_node_fwd_bwd".into(),
        ns_per_iter: s.median() / 10_000.0 * 1e9,
        backend: "autograd",
    });

    println!("\n-- dataset pipeline (prefetch 4 workers vs serial) --");
    let base: Arc<dyn flashlight::data::Dataset> = Arc::new(flashlight::data::TensorDataset::new(vec![
        Tensor::rand([256, 64], -1.0, 1.0),
    ]));
    let slow = Arc::new(flashlight::data::TransformDataset::new(base, |s| {
        std::thread::sleep(std::time::Duration::from_micros(100));
        s
    }));
    let serial = Samples::collect(0, 2, || {
        for i in 0..256 {
            std::hint::black_box(flashlight::data::Dataset::get(slow.as_ref(), i));
        }
    });
    let pf = flashlight::data::PrefetchDataset::new(slow.clone(), 4, 16);
    let prefetch = Samples::collect(0, 2, || {
        for s in pf.iter() {
            std::hint::black_box(s);
        }
    });
    println!(
        "  serial {:.1} ms, prefetch {:.1} ms ({:.1}x)",
        serial.median() * 1e3,
        prefetch.median() * 1e3,
        serial.median() / prefetch.median()
    );
    records.push(Record {
        op: "dataset_serial_256".into(),
        ns_per_iter: serial.median() * 1e9,
        backend: "data-pipeline",
    });
    records.push(Record {
        op: "dataset_prefetch4_256".into(),
        ns_per_iter: prefetch.median() * 1e9,
        backend: "data-pipeline",
    });

    write_bench_json(&records);
}
