//! L3 hot-path microbenchmarks for the performance pass (EXPERIMENTS.md
//! §Perf): GEMM GFLOP/s vs problem size, conv2d, large element-wise maps,
//! allocator throughput, ring all-reduce bandwidth, autograd per-node
//! overhead, and the graph compiler's fused-vs-eager element-wise chain
//! (with op/buffer counts per optimization pass).
//!
//! Besides the human-readable report, the run writes machine-readable
//! JSON at the repo root
//! (`[{"op", "ns_per_iter", "backend", ...extras}, ...]`), replacing any
//! previous run's files; the perf trajectory accumulates across PRs via
//! version control, one snapshot per PR: `BENCH_PR3.json` (the original
//! hot-path set) and `BENCH_PR8.json` (fused-kernel execution engines:
//! interpreted walk vs blockwise vs eager, with an in-run bit-identity
//! check — CI's regression guard reads this file).
//!
//! Run: `cargo bench --bench perf_micro`

use std::sync::Arc;

use flashlight::autograd::{ops, Variable};
use flashlight::memory::{CachingMemoryManager, MemoryManagerAdapter};
use flashlight::tensor::{Conv2dParams, Tensor};
use flashlight::testutil::{write_bench_json, BenchRecord as Record};
use flashlight::util::timing::Samples;

fn gemm_bench(n: usize) -> f64 {
    let a = Tensor::rand([n, n], -1.0, 1.0);
    let b = Tensor::rand([n, n], -1.0, 1.0);
    let s = Samples::collect(2, 5, || {
        std::hint::black_box(a.matmul(&b));
    });
    s.median()
}

fn main() {
    let mut records: Vec<Record> = Vec::new();
    println!("== perf_micro: L3 hot paths ==");
    println!("threads: {}", flashlight::util::parallel::num_threads());

    println!("\n-- GEMM (f32) --");
    for n in [64usize, 128, 256, 512] {
        let secs = gemm_bench(n);
        let gflops = 2.0 * (n as f64).powi(3) / secs / 1e9;
        println!("  {n:>4}x{n:<4}  {gflops:>7.2} GFLOP/s");
        records.push(Record::new(format!("matmul_{n}x{n}"), secs * 1e9, "cpu"));
    }

    println!("\n-- conv2d (im2col+GEMM) --");
    let x = Tensor::rand([8, 16, 32, 32], -1.0, 1.0);
    let w = Tensor::rand([32, 16, 3, 3], -0.1, 0.1);
    let p = Conv2dParams { stride: (1, 1), padding: (1, 1) };
    let s = Samples::collect(2, 5, || {
        std::hint::black_box(x.conv2d(&w, p));
    });
    let flops = 2.0 * 8.0 * 32.0 * 32.0 * 32.0 * 16.0 * 9.0;
    println!("  8x16x32x32 ⋆ 32x16x3x3: {:.2} ms ({:.2} GFLOP/s)", s.median() * 1e3, flops / s.median() / 1e9);
    records.push(Record::new("conv2d_8x16x32x32_k3", s.median() * 1e9, "cpu"));

    println!("\n-- element-wise (gelu over 4M f32) --");
    let big = Tensor::rand([4 * 1024 * 1024], -2.0, 2.0);
    let s = Samples::collect(1, 5, || {
        std::hint::black_box(big.gelu());
    });
    println!("  {:.2} ms  ({:.2} GB/s effective)", s.median() * 1e3, 8.0 * 4.0 * 1048576.0 / s.median() / 1e9);
    records.push(Record::new("gelu_4m", s.median() * 1e9, "cpu"));

    println!("\n-- allocator (caching manager, 64KiB blocks) --");
    let mgr = CachingMemoryManager::unrestricted();
    let s = Samples::collect(1, 5, || {
        let mut live = Vec::new();
        for _ in 0..1000 {
            live.push(mgr.alloc(64 * 1024).unwrap());
        }
        for b in live {
            mgr.unlock(b);
        }
    });
    println!("  {:.1} ns per alloc/free pair", s.median() / 1000.0 * 1e9);
    records.push(Record::new("alloc_free_64k", s.median() / 1000.0 * 1e9, "caching-mem"));

    println!("\n-- ring all-reduce (4 workers, 1M f32) --");
    let s = Samples::collect(1, 3, || {
        let workers = flashlight::dist::init_ring(4);
        std::thread::scope(|sc| {
            for w in workers {
                sc.spawn(move || {
                    use flashlight::dist::DistributedInterface;
                    let t = Tensor::zeros([1 << 20]);
                    std::hint::black_box(w.all_reduce(&t, 1.0));
                });
            }
        });
    });
    println!("  {:.2} ms ({:.2} GB/s algorithmic)", s.median() * 1e3, 4.0 * 4.0 * (1 << 20) as f64 / s.median() / 1e9);
    records.push(Record::new("all_reduce_ring4_1m", s.median() * 1e9, "dist-ring"));

    println!("\n-- autograd overhead (scalar chain, 10k nodes) --");
    let s = Samples::collect(1, 5, || {
        let x = Variable::param(Tensor::from_slice(&[1.0f32], [1]));
        let mut y = x.clone();
        for _ in 0..10_000 {
            y = ops::add_scalar(&y, 1.0);
        }
        y.backward();
    });
    println!("  {:.2} µs per node (fwd+bwd)", s.median() / 10_000.0 * 1e6);
    records.push(Record::new("autograd_node_fwd_bwd", s.median() / 10_000.0 * 1e9, "autograd"));

    println!("\n-- dataset pipeline (prefetch 4 workers vs serial) --");
    let base: Arc<dyn flashlight::data::Dataset> = Arc::new(flashlight::data::TensorDataset::new(vec![
        Tensor::rand([256, 64], -1.0, 1.0),
    ]));
    let slow = Arc::new(flashlight::data::TransformDataset::new(base, |s| {
        std::thread::sleep(std::time::Duration::from_micros(100));
        s
    }));
    let serial = Samples::collect(0, 2, || {
        for i in 0..256 {
            std::hint::black_box(flashlight::data::Dataset::get(slow.as_ref(), i));
        }
    });
    let pf = flashlight::data::PrefetchDataset::new(slow.clone(), 4, 16);
    let prefetch = Samples::collect(0, 2, || {
        for s in pf.iter() {
            std::hint::black_box(s);
        }
    });
    println!(
        "  serial {:.1} ms, prefetch {:.1} ms ({:.1}x)",
        serial.median() * 1e3,
        prefetch.median() * 1e3,
        serial.median() / prefetch.median()
    );
    records.push(Record::new("dataset_serial_256", serial.median() * 1e9, "data-pipeline"));
    records.push(Record::new("dataset_prefetch4_256", prefetch.median() * 1e9, "data-pipeline"));

    graph_compiler_bench(&mut records);

    write_bench_json("BENCH_PR3.json", &records);

    let mut pr8: Vec<Record> = Vec::new();
    fused_exec_bench(&mut pr8);
    write_bench_json("BENCH_PR8.json", &pr8);
}

/// Fused-kernel execution engines head to head (the PR-8 acceptance
/// metric): the blockwise engine must beat the per-element interpreted
/// walk on the fused element-wise chain, target ≥2× elements/s. Also
/// asserts the two engines agree bit-for-bit on this input before timing.
fn fused_exec_bench(records: &mut Vec<Record>) {
    use flashlight::tensor::cpu::CpuBackend;
    use flashlight::tensor::graph::{compile, CompileOptions, CompiledInstr};
    use flashlight::tensor::{BackendGuard, TraceBackend};

    println!("\n-- fused-kernel execution: interpreted vs blockwise vs eager (1M f32, 6 ops) --");
    let n = 1 << 20;
    let a = Tensor::rand([n], -2.0, 2.0);
    let b = Tensor::rand([n], 0.1, 2.0);
    let chain = |x: &Tensor, y: &Tensor| x.add(y).mul(x).tanh().sub(y).abs().sqrt();

    // capture + compile the chain with frozen consts, then pull out the
    // single fused kernel the pipeline produced
    let tracer = TraceBackend::over_cpu_default();
    let root = {
        let _g = BackendGuard::install(tracer.clone());
        let out = chain(&a, &b);
        tracer.interposer().value_ref_of(&out).expect("chain result not traced")
    };
    let raw = tracer.interposer().program();
    let frozen = CompileOptions {
        frozen_consts: [&a, &b]
            .iter()
            .map(|t| tracer.interposer().const_index_of(t).expect("operand not in const pool"))
            .collect(),
        ..Default::default()
    };
    let opt = compile(&raw, &[root], &frozen).expect("pipeline failed");
    let kernel = opt
        .instrs
        .iter()
        .find_map(|i| match i {
            CompiledInstr::Fused(k) => Some(k),
            _ => None,
        })
        .expect("chain must fuse into one kernel");
    let args: Vec<&Tensor> = kernel
        .inputs
        .iter()
        .map(|r| match r {
            flashlight::tensor::ValueRef::Const(c) => &opt.consts[*c],
            other => panic!("chain kernel input should be a const, got {other:?}"),
        })
        .collect();
    let cpu = CpuBackend::shared();

    // bit-identity sanity before timing anything
    let blk = kernel.execute_blockwise(cpu.as_ref(), &args).unwrap().to_vec();
    let interp = kernel.execute_interpreted(cpu.as_ref(), &args).unwrap().to_vec();
    assert_eq!(blk.len(), interp.len());
    for i in 0..blk.len() {
        assert_eq!(blk[i].to_bits(), interp[i].to_bits(), "engine mismatch at element {i}");
    }

    let eager_t = Samples::collect(1, 5, || {
        std::hint::black_box(chain(&a, &b).to_vec());
    });
    let interp_t = Samples::collect(1, 5, || {
        std::hint::black_box(kernel.execute_interpreted(cpu.as_ref(), &args).unwrap().to_vec());
    });
    let block_t = Samples::collect(1, 5, || {
        std::hint::black_box(kernel.execute_blockwise(cpu.as_ref(), &args).unwrap().to_vec());
    });

    let eps = |secs: f64| n as f64 / secs;
    println!(
        "  eager {:.2} ms | interpreted {:.2} ms | blockwise {:.2} ms",
        eager_t.median() * 1e3,
        interp_t.median() * 1e3,
        block_t.median() * 1e3
    );
    println!(
        "  blockwise: {:.1} Melem/s ({:.2}x vs interpreted, {:.2}x vs eager)",
        eps(block_t.median()) / 1e6,
        interp_t.median() / block_t.median(),
        eager_t.median() / block_t.median()
    );

    let mut rec = Record::new("fused_chain6_1m_eager", eager_t.median() * 1e9, "cpu");
    rec.extras.push(("elements_per_s", eps(eager_t.median())));
    records.push(rec);
    let mut rec = Record::new("fused_chain6_1m_interp", interp_t.median() * 1e9, "fused-interp");
    rec.extras.push(("elements_per_s", eps(interp_t.median())));
    records.push(rec);
    let mut rec =
        Record::new("fused_chain6_1m_blockwise", block_t.median() * 1e9, "fused-blockwise");
    rec.extras.push(("elements_per_s", eps(block_t.median())));
    rec.extras.push(("speedup_vs_interp", interp_t.median() / block_t.median()));
    rec.extras.push(("speedup_vs_eager", eager_t.median() / block_t.median()));
    records.push(rec);
}

/// Fused-vs-eager element-wise chain through the graph compiler, with
/// op/buffer counts per pass (the PR-3 acceptance metric: the compiled
/// chain executes fewer ops and allocates fewer buffers than eager, at
/// equal-or-better wall time).
fn graph_compiler_bench(records: &mut Vec<Record>) {
    use flashlight::tensor::cpu::CpuBackend;
    use flashlight::tensor::graph::{compile, CompileOptions};
    use flashlight::tensor::{BackendGuard, TraceBackend};

    println!("\n-- graph compiler: element-wise chain (1M f32, 6 ops) --");
    let n = 1 << 20;
    let a = Tensor::rand([n], -2.0, 2.0);
    let b = Tensor::rand([n], 0.1, 2.0);
    let chain = |x: &Tensor, y: &Tensor| x.add(y).mul(x).tanh().sub(y).abs().sqrt();

    // eager: six separate kernels, six intermediate buffers
    let eager = Samples::collect(1, 5, || {
        std::hint::black_box(chain(&a, &b).to_vec());
    });

    // capture the chain once, then compile it twice: a structure-
    // preserving lowering (one dispatched kernel + buffer per op, the
    // eager plan) and the full pipeline (the a/b constants are frozen so
    // folding cannot bake their values in). The old lazy backend also
    // single-passed straight chains, so its honest comparator is the
    // fused row — which additionally shares diamond subgraphs and runs
    // the pass in parallel, where the old RPN walk was serial.
    let tracer = TraceBackend::over_cpu_default();
    let root = {
        let _g = BackendGuard::install(tracer.clone());
        let out = chain(&a, &b);
        tracer.interposer().value_ref_of(&out).expect("chain result not traced")
    };
    let raw = tracer.interposer().program();
    let frozen = CompileOptions {
        frozen_consts: [&a, &b]
            .iter()
            .map(|t| tracer.interposer().const_index_of(t).expect("operand not in const pool"))
            .collect(),
        ..Default::default()
    };
    let unopt = compile(&raw, &[root], &CompileOptions::none()).expect("lowering failed");
    let opt = compile(&raw, &[root], &frozen).expect("pipeline failed");

    let cpu = CpuBackend::shared();
    let unfused_t = Samples::collect(1, 5, || {
        std::hint::black_box(unopt.run(cpu.as_ref()).unwrap().remove(0).to_vec());
    });
    let fused_t = Samples::collect(1, 5, || {
        std::hint::black_box(opt.run(cpu.as_ref()).unwrap().remove(0).to_vec());
    });
    let (_, ustats) = unopt.run_detailed(cpu.as_ref(), &[]).expect("unopt run failed");
    let (_, ostats) = opt.run_detailed(cpu.as_ref(), &[]).expect("opt run failed");

    println!(
        "  eager {:.2} ms | compiled-unfused {:.2} ms | compiled-fused {:.2} ms ({:.2}x vs eager)",
        eager.median() * 1e3,
        unfused_t.median() * 1e3,
        fused_t.median() * 1e3,
        eager.median() / fused_t.median()
    );
    println!("  pipeline: {}", opt.report.summary());
    println!(
        "  ops {} -> {} (primitive {}), buffers {} -> {} slots, peak bytes {} -> {}",
        ustats.executed_instrs,
        ostats.executed_instrs,
        ostats.executed_ops,
        ustats.buffer_slots,
        ostats.buffer_slots,
        ustats.naive_peak_bytes,
        ostats.planned_peak_bytes
    );

    let mut eager_rec = Record::new("ew_chain6_1m_eager", eager.median() * 1e9, "cpu");
    eager_rec.extras.push(("ops_executed", 6.0));
    eager_rec.extras.push(("buffers", 6.0));
    records.push(eager_rec);

    let mut urec = Record::new("ew_chain6_1m_unfused", unfused_t.median() * 1e9, "graph-lowered");
    urec.extras.push(("instrs_executed", ustats.executed_instrs as f64));
    urec.extras.push(("buffers_planned", ustats.buffer_slots as f64));
    urec.extras.push(("peak_bytes_planned", ustats.planned_peak_bytes as f64));
    urec.extras.push(("peak_bytes_naive", ustats.naive_peak_bytes as f64));
    records.push(urec);

    let mut rec = Record::new("ew_chain6_1m_fused", fused_t.median() * 1e9, "graph-compiled");
    rec.extras.push(("instrs_executed", ostats.executed_instrs as f64));
    rec.extras.push(("primitive_ops", ostats.executed_ops as f64));
    rec.extras.push(("buffers_planned", ostats.buffer_slots as f64));
    rec.extras.push(("buffers_naive", ustats.executed_instrs as f64));
    rec.extras.push(("peak_bytes_planned", ostats.planned_peak_bytes as f64));
    rec.extras.push(("peak_bytes_naive", ustats.naive_peak_bytes as f64));
    for pass in &opt.report.passes {
        match pass.pass {
            "dce" => rec.extras.push(("ops_after_dce", pass.ops_after as f64)),
            "fold" => rec.extras.push(("ops_after_fold", pass.ops_after as f64)),
            "cse" => rec.extras.push(("ops_after_cse", pass.ops_after as f64)),
            "fuse" => rec.extras.push(("ops_after_fuse", pass.ops_after as f64)),
            _ => {}
        }
    }
    records.push(rec);
}
