//! Tables 1 & 4 reproduction: codebase complexity metrics.
//!
//! Measures this repository the way the paper measures Flashlight —
//! lines of code (core vs tensor-library split), binary size of the `fl`
//! launcher, operator count (the `TensorBackend` + autograd interfaces =
//! "the full implementation requirements for a tensor backend"), and the
//! number of operator implementations that perform ADD / CONV / SUM —
//! printed beside the paper's quoted PyTorch/TensorFlow rows for shape
//! comparison (those frameworks cannot be built on this offline testbed).
//!
//! Run: `cargo bench --bench complexity`

use std::path::Path;

fn count_lines(dir: &Path, tensor_lib: &mut usize, other: &mut usize) {
    for entry in std::fs::read_dir(dir).expect("read_dir") {
        let entry = entry.unwrap();
        let path = entry.path();
        if path.is_dir() {
            count_lines(&path, tensor_lib, other);
        } else if path.extension().map(|e| e == "rs" || e == "py").unwrap_or(false) {
            let lines = std::fs::read_to_string(&path).map(|s| s.lines().count()).unwrap_or(0);
            let p = path.to_string_lossy();
            // tensor-library components (Table 4's split): backends + kernels
            if p.contains("tensor/cpu") || p.contains("tensor/lazy") || p.contains("kernels") {
                *tensor_lib += lines;
            } else {
                *other += lines;
            }
        }
    }
}

/// Count methods on a trait by scanning its source (the paper counts
/// operator schemas the same way).
fn count_trait_methods(src: &str, trait_name: &str) -> usize {
    let Some(start) = src.find(&format!("pub trait {trait_name}")) else { return 0 };
    let body = &src[start..];
    // count `fn ` declarations until the trait's closing brace at depth 0
    let mut depth = 0usize;
    let mut count = 0usize;
    let mut entered = false;
    for (i, ch) in body.char_indices() {
        match ch {
            '{' => {
                depth += 1;
                entered = true;
            }
            '}' => {
                depth -= 1;
                if entered && depth == 0 {
                    return count;
                }
            }
            'f' if depth == 1 && body[i..].starts_with("fn ") => count += 1,
            _ => {}
        }
    }
    count
}

fn count_role(dir: &Path, needle: &str, acc: &mut usize) {
    for entry in std::fs::read_dir(dir).expect("read_dir") {
        let entry = entry.unwrap();
        let path = entry.path();
        if path.is_dir() {
            count_role(&path, needle, acc);
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let src = std::fs::read_to_string(&path).unwrap_or_default();
            // count op implementations whose name mentions the role
            for line in src.lines() {
                let l = line.trim_start();
                if l.starts_with("fn ") || l.starts_with("pub fn ") {
                    let name = l.trim_start_matches("pub ").trim_start_matches("fn ");
                    let name = name.split(['(', '<']).next().unwrap_or("");
                    if name.contains(needle) {
                        *acc += 1;
                    }
                }
            }
        }
    }
}

fn main() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let rust_src = root.join("rust/src");
    let py_src = root.join("python/compile");

    let (mut tensor_lib, mut other) = (0usize, 0usize);
    count_lines(&rust_src, &mut tensor_lib, &mut other);
    count_lines(&py_src, &mut tensor_lib, &mut other);
    let total = tensor_lib + other;

    let backend_src = std::fs::read_to_string(rust_src.join("tensor/backend.rs")).unwrap();
    let ops_src = std::fs::read_to_string(rust_src.join("autograd/ops.rs")).unwrap();
    let backend_ops = count_trait_methods(&backend_src, "TensorBackend");
    let autograd_ops = ops_src
        .lines()
        .filter(|l| l.trim_start().starts_with("pub fn "))
        .count();
    let operators = backend_ops + autograd_ops;

    // role counts over the *reference implementation* (tensor/cpu): the
    // paper's metric is "how many places implement addition" — interposed
    // wrappers (interpose.rs: lazy, xla, profiling, trace, bloat) forward
    // rather than implement, so only the cpu backend is scanned
    let cpu_src = rust_src.join("tensor/cpu");
    let (mut adds, mut convs, mut sums) = (0usize, 0usize, 0usize);
    count_role(&cpu_src, "add", &mut adds);
    count_role(&cpu_src, "conv", &mut convs);
    count_role(&cpu_src, "sum", &mut sums);

    let binary = root.join("target/release/fl");
    let bin_mb = std::fs::metadata(&binary)
        .map(|m| m.len() as f64 / (1024.0 * 1024.0))
        .ok();

    println!("== Table 1: framework complexity (paper values quoted for PT/TF) ==");
    println!("{:<34} {:>10} {:>12} {:>14}", "METRIC", "PyTorch*", "TensorFlow*", "flashlight-rs");
    match bin_mb {
        Some(mb) => println!("{:<34} {:>10} {:>12} {:>14.1}", "binary size (MB)", 527, 768, mb),
        None => println!(
            "{:<34} {:>10} {:>12} {:>14}",
            "binary size (MB)", 527, 768, "(build --release first)"
        ),
    }
    println!("{:<34} {:>10} {:>12} {:>14}", "lines of code", "1,798,292", "1,306,159", total);
    println!("{:<34} {:>10} {:>12} {:>14}", "number of operators", "2,166", "1,423", operators);
    println!("{:<34} {:>10} {:>12} {:>14}", "ops performing ADD", 55, 20, adds);
    println!("{:<34} {:>10} {:>12} {:>14}", "ops performing CONV", 85, 30, convs);
    println!("{:<34} {:>10} {:>12} {:>14}", "ops performing SUM", 25, 10, sums);
    println!("  (*paper-reported values; PT/TF cannot be built offline — DESIGN.md)");

    println!("\n== Table 4: with / without tensor-library components ==");
    println!("{:<34} {:>14}", "METRIC", "flashlight-rs");
    println!("{:<34} {:>14}", "LoC (no tensor lib)", other);
    println!("{:<34} {:>14}", "LoC (with tensor lib)", total);
    println!("{:<34} {:>14}", "tensor-lib LoC", tensor_lib);
    println!("{:<34} {:>14}", "backend interface ops", backend_ops);
    println!("{:<34} {:>14}", "autograd interface ops", autograd_ops);

    // shape assertions: the paper's qualitative claims must hold
    assert!(total < 100_000, "LoC should stay orders of magnitude below PT/TF");
    assert!(operators < 200, "operator surface should stay ~2 orders below PT/TF");
    assert!(adds <= 6, "few sources of truth for add (got {adds})");
    assert!(convs <= 12, "conv implementations bounded (got {convs})");
    assert!(sums <= 12, "sum implementations bounded (got {sums})");
}
