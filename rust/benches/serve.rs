//! Serving-engine throughput snapshot -> BENCH_PR5.json.
//!
//! Two comparisons, matching the acceptance criteria:
//! - **batched vs unbatched** scoring tokens/s through the compiled
//!   session (dynamic batcher at max_batch 8 vs one-by-one service), and
//! - **cached vs uncached** autoregressive decode tokens/s (per-layer KV
//!   cache vs full-context recompute).
//!
//! Run: `cargo bench --bench serve`

use std::sync::Arc;
use std::time::{Duration, Instant};

use flashlight::models::BertLike;
use flashlight::serve::{generate, Engine, EngineConfig, GenerateOptions, Sampling};
use flashlight::testutil::{write_bench_json, BenchRecord};
use flashlight::util::rng::Rng;
use flashlight::Tensor;

const VOCAB: usize = 64;
const SEQ: usize = 16;
const REQUESTS: usize = 64;
const PROMPT: usize = 8;
const NEW_TOKENS: usize = 32;

fn main() {
    flashlight::util::rng::seed(42);
    let model = Arc::new(BertLike::new(VOCAB, 64, 4, 2, PROMPT + NEW_TOKENS + SEQ));
    let mut rng = Rng::new(7);
    let inputs: Vec<Tensor> = (0..REQUESTS)
        .map(|_| {
            let ids: Vec<i64> = (0..SEQ).map(|_| rng.below(VOCAB) as i64).collect();
            Tensor::from_slice(&ids, [SEQ])
        })
        .collect();
    let mut records = Vec::new();

    // ---- batched vs unbatched scoring ------------------------------------
    let cfg_unbatched = EngineConfig {
        max_batch_size: 1,
        max_wait: Duration::from_micros(100),
        workers: 1,
        ..Default::default()
    };
    let engine = Engine::start_lm(Arc::clone(&model), SEQ, &[1], &cfg_unbatched).unwrap();
    let t0 = Instant::now();
    for x in &inputs {
        let _ = engine.infer(x.copy()).unwrap();
    }
    let unbatched_secs = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    engine.shutdown();
    let unbatched_tps = (REQUESTS * SEQ) as f64 / unbatched_secs;
    let mut row = BenchRecord::new(
        "serve_score_unbatched",
        unbatched_secs * 1e9 / REQUESTS as f64,
        "cpu",
    );
    row.extras.push(("tokens_per_sec", unbatched_tps));
    row.extras.push(("requests", REQUESTS as f64));
    row.extras.push(("batches", stats.batcher.batches as f64));
    row.extras.push(("latency_p50_us", stats.batcher.latency_p50_us));
    row.extras.push(("latency_p99_us", stats.batcher.latency_p99_us));
    records.push(row);

    let cfg_batched = EngineConfig {
        max_batch_size: 8,
        max_wait: Duration::from_millis(5),
        workers: 2,
        ..Default::default()
    };
    let engine = Engine::start_lm(Arc::clone(&model), SEQ, &[1, 8], &cfg_batched).unwrap();
    let t0 = Instant::now();
    let handles: Vec<_> = inputs.iter().map(|x| engine.submit(x.copy())).collect();
    for h in handles {
        let _ = h.wait().unwrap();
    }
    let batched_secs = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    engine.shutdown();
    let batched_tps = (REQUESTS * SEQ) as f64 / batched_secs;
    let mut row = BenchRecord::new(
        "serve_score_batched",
        batched_secs * 1e9 / REQUESTS as f64,
        "cpu",
    );
    row.extras.push(("tokens_per_sec", batched_tps));
    row.extras.push(("requests", REQUESTS as f64));
    row.extras.push(("batches", stats.batcher.batches as f64));
    row.extras.push(("mean_batch_fill", stats.batcher.mean_batch_fill));
    row.extras.push(("latency_p50_us", stats.batcher.latency_p50_us));
    row.extras.push(("latency_p99_us", stats.batcher.latency_p99_us));
    row.extras.push(("speedup_vs_unbatched", batched_tps / unbatched_tps));
    records.push(row);
    println!(
        "scoring: unbatched {unbatched_tps:.0} tok/s, batched {batched_tps:.0} tok/s \
         ({:.2}x)",
        batched_tps / unbatched_tps
    );

    // ---- cached vs uncached decode ---------------------------------------
    let prompt: Vec<i64> = (0..PROMPT).map(|i| (i * 5 % VOCAB) as i64).collect();
    let opts = |use_cache| GenerateOptions {
        max_new_tokens: NEW_TOKENS,
        sampling: Sampling::Greedy,
        seed: 3,
        use_cache,
        record_logits: false,
    };
    let uncached = generate(&model, &prompt, &opts(false)).unwrap();
    let cached = generate(&model, &prompt, &opts(true)).unwrap();
    assert_eq!(cached.tokens, uncached.tokens, "decode paths must agree bitwise");
    for (name, rep) in [("decode_uncached", &uncached), ("decode_cached", &cached)] {
        let mut row = BenchRecord::new(
            name.to_string(),
            rep.decode_secs * 1e9 / rep.generated.max(1) as f64,
            "cpu",
        );
        row.extras.push(("tokens_per_sec", rep.tokens_per_sec));
        row.extras.push(("generated", rep.generated as f64));
        row.extras.push(("prefill_secs", rep.prefill_secs));
        records.push(row);
    }
    if uncached.tokens_per_sec > 0.0 {
        records.last_mut().unwrap().extras.push((
            "speedup_vs_uncached",
            cached.tokens_per_sec / uncached.tokens_per_sec,
        ));
    }
    println!(
        "decode: uncached {:.1} tok/s, cached {:.1} tok/s ({:.2}x)",
        uncached.tokens_per_sec,
        cached.tokens_per_sec,
        cached.tokens_per_sec / uncached.tokens_per_sec.max(1e-9)
    );

    write_bench_json("BENCH_PR5.json", &records);
}
