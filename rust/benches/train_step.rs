//! Compiled-vs-eager train-step benchmark (PR 4): one MLP classifier
//! step — forward + backward + clip + SGD-momentum update — timed as the
//! eager loop and as the [`flashlight::coordinator::compile_step`]
//! program, plus the compiler's per-pass op counts and the memory plan's
//! planned/naive peak bytes with buffer donation on and off.
//!
//! Writes machine-readable `BENCH_PR4.json` at the repo root (same row
//! format as the earlier bench snapshots: `[{"op", "ns_per_iter",
//! "backend", ...extras}]`).
//!
//! Run: `cargo bench --bench train_step`

use std::time::Instant;

use flashlight::autograd::Variable;
use flashlight::coordinator::trainer::make_optimizer;
use flashlight::coordinator::{compile_step, BatchSpec, TrainConfig};
use flashlight::models::mlp;
use flashlight::nn::{categorical_cross_entropy, Module};
use flashlight::optim::{clip_grad_norm, Optimizer};
use flashlight::tensor::{default_backend, Tensor};
use flashlight::testutil::{write_bench_json, BenchRecord as Record};

fn fixed_batch(b: usize, feat: usize, classes: usize) -> Vec<Tensor> {
    let xs: Vec<f32> = (0..b * feat).map(|j| ((j * 37 % 19) as f32) * 0.1 - 0.9).collect();
    let ys: Vec<i64> = (0..b).map(|j| (j % classes) as i64).collect();
    vec![Tensor::from_slice(&xs, [b, feat]), Tensor::from_slice(&ys, [b])]
}

fn main() {
    let (feat, hidden, classes, b) = (64usize, 64usize, 10usize, 32usize);
    let iters = 60usize;
    let warmup = 5usize;
    let cfg = TrainConfig {
        optimizer: "sgd".into(),
        lr: 0.01,
        grad_clip: 1.0,
        ..Default::default()
    };
    let batch = fixed_batch(b, feat, classes);
    let mut records = Vec::new();
    println!("train-step benchmark: MLP {feat}->{hidden}->{classes}, batch {b}, {iters} iters");

    // ---- eager loop -----------------------------------------------------
    let mut model = mlp(&[feat, hidden, classes]);
    model.set_train(true);
    let mut opt = make_optimizer(&cfg, model.params()).expect("optimizer");
    let eager_step = |model: &mut flashlight::nn::Sequential, opt: &mut Box<dyn Optimizer>| {
        let out = model.forward(&Variable::constant(batch[0].clone()));
        let loss = categorical_cross_entropy(&out, &batch[1]);
        loss.backward();
        clip_grad_norm(opt.params(), cfg.grad_clip);
        opt.step();
        opt.zero_grad();
    };
    for _ in 0..warmup {
        eager_step(&mut model, &mut opt);
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        eager_step(&mut model, &mut opt);
    }
    let eager_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let eager_sps = 1e9 / eager_ns;
    println!("eager:    {:>10.0} ns/step  ({eager_sps:.1} steps/s)", eager_ns);
    records.push(Record {
        op: "train_step_eager".into(),
        ns_per_iter: eager_ns,
        backend: "cpu",
        extras: vec![("steps_per_sec", eager_sps)],
    });

    // ---- compiled step --------------------------------------------------
    let mut model = mlp(&[feat, hidden, classes]);
    model.set_train(true);
    let step = compile_step(&model, &cfg, &BatchSpec::like(&batch)).expect("compile_step");
    let report = step.report();
    println!("compile report: {}", report.summary());
    let traced_ops = report.passes.first().map(|p| p.ops_before).unwrap_or(0);
    let prog = step.program();
    let be = default_backend();
    let mut params: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
    let mut state = step.init_state(&params);
    for _ in 0..warmup {
        let res = step.run(be.as_ref(), params, state, &batch, true).expect("step");
        params = res.params;
        state = res.state;
    }
    let t0 = Instant::now();
    let mut last_stats = None;
    for _ in 0..iters {
        let res = step.run(be.as_ref(), params, state, &batch, true).expect("step");
        params = res.params;
        state = res.state;
        last_stats = Some(res.stats);
    }
    let compiled_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let compiled_sps = 1e9 / compiled_ns;
    let stats = last_stats.expect("at least one iteration");
    println!(
        "compiled: {:>10.0} ns/step  ({compiled_sps:.1} steps/s)  \
         [{} instrs / {} primitive ops, traced {traced_ops}]",
        compiled_ns,
        prog.len(),
        prog.primitive_op_count()
    );
    records.push(Record {
        op: "train_step_compiled".into(),
        ns_per_iter: compiled_ns,
        backend: "cpu",
        extras: vec![
            ("steps_per_sec", compiled_sps),
            ("traced_ops", traced_ops as f64),
            ("compiled_instrs", prog.len() as f64),
            ("compiled_primitive_ops", prog.primitive_op_count() as f64),
            ("dce_removed", report.changed_by("dce") as f64),
            ("fold_removed", report.changed_by("fold") as f64),
            ("cse_merged", report.changed_by("cse") as f64),
            ("fuse_collapsed", report.changed_by("fuse") as f64),
            ("executed_ops", stats.executed_ops as f64),
        ],
    });

    // ---- memory plan: donation on vs off --------------------------------
    let ps = |src: &[Tensor]| -> Vec<Tensor> { src.iter().map(|p| p.copy()).collect() };
    let run_mem = |donate: bool| {
        let p = ps(&params);
        let st = step.init_state(&p);
        step.run(be.as_ref(), p, st, &batch, donate).expect("step").stats
    };
    let kept = run_mem(false);
    let donated = run_mem(true);
    println!(
        "memory:   planned peak {} B (donating) vs {} B (keeping inputs), naive {} B, \
         donated {} B/step",
        donated.planned_peak_bytes, kept.planned_peak_bytes, kept.naive_peak_bytes,
        donated.donated_bytes
    );
    records.push(Record {
        op: "train_step_memplan".into(),
        ns_per_iter: 0.0,
        backend: "cpu",
        extras: vec![
            ("planned_peak_bytes_donate", donated.planned_peak_bytes as f64),
            ("planned_peak_bytes_keep", kept.planned_peak_bytes as f64),
            ("naive_peak_bytes", kept.naive_peak_bytes as f64),
            ("donated_bytes_per_step", donated.donated_bytes as f64),
            ("buffer_slots", donated.buffer_slots as f64),
        ],
    });

    write_bench_json("BENCH_PR4.json", &records);
}
