//! Bucket-compiled decode iterations + chunked-prefill interference
//! snapshot -> BENCH_PR9.json.
//!
//! Two measurements, matching the PR's acceptance criteria:
//!
//! - **decode iteration latency vs batch size**: the continuous batcher's
//!   `[B, 1]` decode step through the pre-compiled segment programs
//!   ([`CompiledDecodeStep`]) vs the eager
//!   [`BertLike::logits_decode_batch`], over identical token streams and
//!   caches — asserted bitwise-identical in the same run, so the speedup
//!   is measured on provably the same computation;
//! - **prefill interference p99**: short requests decoding through the
//!   [`ContinuousBatcher`] while one very long prompt is admitted
//!   mid-flight, with whole-prompt prefill vs Sarathi-style chunked
//!   prefill (`prefill_chunk`) — chunking bounds how long a pass can
//!   stall the cohabiting decodes, which shows up as a lower short-request
//!   p99. Token streams are asserted identical across the two modes.
//!
//! Run: `cargo bench --bench serve_decode`

use std::sync::Arc;
use std::time::{Duration, Instant};

use flashlight::autograd::no_grad;
use flashlight::memory::KvPagePool;
use flashlight::models::BertLike;
use flashlight::nn::PagedKvCache;
use flashlight::serve::{
    CompiledDecodeStep, ContinuousBatcher, ContinuousConfig, GenerateOptions, Sampling,
};
use flashlight::testutil::{write_bench_json, BenchRecord};
use flashlight::Tensor;

// ---- part 1: compiled vs eager decode iterations ---------------------------

const VOCAB: usize = 64;
const PREFILL: usize = 16;
const STEPS: usize = 24;
const REPS: usize = 3;
const BATCHES: [usize; 4] = [1, 2, 4, 8];

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Fresh per-request caches, each prefilled with `PREFILL` tokens.
fn fresh_caches(model: &BertLike, b: usize) -> Vec<PagedKvCache> {
    let page_tokens = 8;
    let pages = b * (PREFILL + STEPS).div_ceil(page_tokens);
    let pool = KvPagePool::new(model.kv_pool_config(page_tokens, pages));
    (0..b)
        .map(|r| {
            let mut cache = PagedKvCache::new(Arc::clone(&pool));
            cache.reserve(PREFILL + STEPS).expect("bench pool sized exactly");
            let prompt: Vec<i64> =
                (0..PREFILL).map(|j| ((r * 13 + j * 5) % VOCAB) as i64).collect();
            let ids = Tensor::from_slice(&prompt, [1, PREFILL]);
            no_grad(|| model.logits_paged(&ids, &mut cache));
            cache
        })
        .collect()
}

/// The fixed token fed to row `r` at step `t` — identical for both modes,
/// so the bitwise comparison runs over the exact same schedule.
fn token_at(r: usize, t: usize) -> i64 {
    ((r * 7 + t * 3) % VOCAB) as i64
}

/// One timed rep of `STEPS` decode iterations at batch `b`. Returns the
/// decode-only elapsed seconds plus (when `record`) each step's logit bits.
fn decode_rep(
    model: &BertLike,
    step: Option<&CompiledDecodeStep>,
    b: usize,
    record: bool,
) -> (f64, Vec<Vec<u32>>) {
    let mut caches = fresh_caches(model, b);
    let mut trace = Vec::new();
    let t0 = Instant::now();
    for t in 0..STEPS {
        let tokens: Vec<i64> = (0..b).map(|r| token_at(r, t)).collect();
        let mut refs: Vec<&mut PagedKvCache> = caches.iter_mut().collect();
        let logits = match step {
            Some(s) => no_grad(|| s.step(model, &tokens, &mut refs))
                .expect("compiled step")
                .expect("every bench batch size has a bucket"),
            None => {
                let ids = Tensor::from_slice(&tokens, [b, 1]);
                no_grad(|| model.logits_decode_batch(&ids, &mut refs)).tensor()
            }
        };
        if record {
            trace.push(bits(&logits.to_vec()));
        }
    }
    (t0.elapsed().as_secs_f64(), trace)
}

fn bench_decode_iterations(records: &mut Vec<BenchRecord>) {
    flashlight::util::rng::seed(42);
    let model = BertLike::new(VOCAB, 64, 4, 2, PREFILL + STEPS + 8);
    let step = CompiledDecodeStep::compile(&model, &BATCHES).expect("decode buckets compile");
    for &b in &BATCHES {
        // parity first: the two modes must be bit-identical step by step
        let (_, eager_trace) = decode_rep(&model, None, b, true);
        let (_, compiled_trace) = decode_rep(&model, Some(&step), b, true);
        assert_eq!(eager_trace, compiled_trace, "compiled decode diverged from eager at b={b}");

        let mut eager_best = f64::INFINITY;
        let mut compiled_best = f64::INFINITY;
        for _ in 0..REPS {
            eager_best = eager_best.min(decode_rep(&model, None, b, false).0);
            compiled_best = compiled_best.min(decode_rep(&model, Some(&step), b, false).0);
        }
        let eager_ns = eager_best * 1e9 / STEPS as f64;
        let compiled_ns = compiled_best * 1e9 / STEPS as f64;
        let mut row = BenchRecord::new(
            match b {
                1 => "serve_decode_iter_b1_eager",
                2 => "serve_decode_iter_b2_eager",
                4 => "serve_decode_iter_b4_eager",
                _ => "serve_decode_iter_b8_eager",
            },
            eager_ns,
            "cpu",
        );
        row.extras.push(("batch", b as f64));
        row.extras.push(("steps", STEPS as f64));
        records.push(row);
        let mut row = BenchRecord::new(
            match b {
                1 => "serve_decode_iter_b1_compiled",
                2 => "serve_decode_iter_b2_compiled",
                4 => "serve_decode_iter_b4_compiled",
                _ => "serve_decode_iter_b8_compiled",
            },
            compiled_ns,
            "cpu",
        );
        row.extras.push(("batch", b as f64));
        row.extras.push(("steps", STEPS as f64));
        row.extras.push(("speedup_vs_eager", eager_ns / compiled_ns));
        records.push(row);
        println!(
            "decode iter b={b}: eager {:.1}us, compiled {:.1}us ({:.2}x)",
            eager_ns / 1e3,
            compiled_ns / 1e3,
            eager_ns / compiled_ns
        );
    }
}

// ---- part 2: chunked-prefill interference ----------------------------------

const SHORTS: usize = 12;
const SHORT_PROMPT: usize = 8;
const SHORT_NEW: usize = 4;
const LONG_PROMPT: usize = 384;
const CHUNK: usize = 32;

fn p99(latencies: &[f64]) -> f64 {
    let mut sorted = latencies.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64) * 0.99).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

/// Serve `SHORTS` short decodes while one `LONG_PROMPT`-token admission
/// lands mid-flight. Returns the shorts' p99 latency (seconds) and every
/// request's token stream (shorts in submit order, then the long one) —
/// the streams must not depend on the prefill policy.
fn interference(model: &Arc<BertLike>, prefill_chunk: Option<usize>) -> (f64, Vec<Vec<i64>>) {
    let cfg = ContinuousConfig {
        max_active: 4,
        page_tokens: 16,
        pool_pages: None,
        decode_buckets: None,
        prefill_chunk,
    };
    let batcher = Arc::new(ContinuousBatcher::start(Arc::clone(model), &cfg).unwrap());
    std::thread::scope(|s| {
        let shorts: Vec<_> = (0..SHORTS)
            .map(|i| {
                let b = Arc::clone(&batcher);
                s.spawn(move || {
                    std::thread::sleep(Duration::from_millis(2 * i as u64));
                    let prompt: Vec<i64> =
                        (0..SHORT_PROMPT).map(|j| ((i * 13 + j * 5) % VOCAB) as i64).collect();
                    let opts = GenerateOptions {
                        max_new_tokens: SHORT_NEW,
                        sampling: Sampling::Greedy,
                        seed: 0,
                        ..Default::default()
                    };
                    let t0 = Instant::now();
                    let report = b.generate(&prompt, &opts).unwrap();
                    (t0.elapsed().as_secs_f64(), report.tokens)
                })
            })
            .collect();
        let long = {
            let b = Arc::clone(&batcher);
            s.spawn(move || {
                // land after decode traffic is flowing, before it drains
                std::thread::sleep(Duration::from_millis(3));
                let prompt: Vec<i64> =
                    (0..LONG_PROMPT).map(|j| (j * 11 % VOCAB) as i64).collect();
                let opts = GenerateOptions {
                    max_new_tokens: SHORT_NEW,
                    sampling: Sampling::Greedy,
                    seed: 0,
                    ..Default::default()
                };
                b.generate(&prompt, &opts).unwrap().tokens
            })
        };
        let mut latencies = Vec::with_capacity(SHORTS);
        let mut streams = Vec::with_capacity(SHORTS + 1);
        for h in shorts {
            let (lat, tokens) = h.join().unwrap();
            latencies.push(lat);
            streams.push(tokens);
        }
        streams.push(long.join().unwrap());
        batcher.shutdown();
        (p99(&latencies), streams)
    })
}

fn bench_prefill_interference(records: &mut Vec<BenchRecord>) {
    flashlight::util::rng::seed(42);
    let model = Arc::new(BertLike::new(VOCAB, 64, 4, 2, LONG_PROMPT + 32));
    let (whole_p99, whole_streams) = interference(&model, None);
    let (chunked_p99, chunked_streams) = interference(&model, Some(CHUNK));
    assert_eq!(
        whole_streams, chunked_streams,
        "chunked prefill must not change any request's token stream"
    );
    let mut row = BenchRecord::new("serve_prefill_interference_unchunked", whole_p99 * 1e9, "cpu");
    row.extras.push(("latency_p99_us", whole_p99 * 1e6));
    row.extras.push(("short_requests", SHORTS as f64));
    row.extras.push(("long_prompt_tokens", LONG_PROMPT as f64));
    records.push(row);
    let mut row =
        BenchRecord::new("serve_prefill_interference_chunked32", chunked_p99 * 1e9, "cpu");
    row.extras.push(("latency_p99_us", chunked_p99 * 1e6));
    row.extras.push(("prefill_chunk", CHUNK as f64));
    row.extras.push(("p99_vs_unchunked", chunked_p99 / whole_p99));
    records.push(row);
    println!(
        "prefill interference: whole-prompt p99 {:.1}ms, chunked({CHUNK}) p99 {:.1}ms ({:.2}x)",
        whole_p99 * 1e3,
        chunked_p99 * 1e3,
        chunked_p99 / whole_p99
    );
}

fn main() {
    let mut records = Vec::new();
    bench_decode_iterations(&mut records);
    bench_prefill_interference(&mut records);
    write_bench_json("BENCH_PR9.json", &records);
}
