//! Continuous vs request-level batching snapshot -> BENCH_PR6.json.
//!
//! Three comparisons, matching the PR's acceptance criteria:
//! - **goodput**: 32 mixed-length generation requests through the
//!   continuous (iteration-level) scheduler vs a request-level baseline
//!   that forms FIFO batches of 4 and holds every slot until the whole
//!   batch finishes (head-of-line blocking, the PR 5 serving shape);
//! - **tail latency**: per-request p99 under the same workload; and
//! - **solo latency**: a lone request through the continuous scheduler vs
//!   a direct `generate()` call (the no-regression guard).
//!
//! Both paths decode greedily over the same paged KV pool geometry, so
//! the only variable is the scheduling policy.
//!
//! Run: `cargo bench --bench serve_continuous`

use std::sync::Arc;
use std::time::Instant;

use flashlight::autograd::no_grad;
use flashlight::memory::KvPagePool;
use flashlight::models::BertLike;
use flashlight::nn::PagedKvCache;
use flashlight::serve::{generate, ContinuousBatcher, ContinuousConfig, GenerateOptions, Sampling};
use flashlight::testutil::{write_bench_json, BenchRecord};
use flashlight::util::rng::Rng;
use flashlight::Tensor;

const VOCAB: usize = 64;
const PROMPT: usize = 8;
const REQUESTS: usize = 32;
const BATCH: usize = 4;
const PAGE_TOKENS: usize = 8;
/// Generation budgets cycle short..long, so every request-level batch of
/// 4 contains one straggler the other three slots must wait out.
const NEW_TOKENS: [usize; 4] = [4, 8, 16, 32];

fn mixed_requests(rng: &mut Rng) -> Vec<(Vec<i64>, usize)> {
    (0..REQUESTS)
        .map(|i| {
            let prompt: Vec<i64> = (0..PROMPT).map(|_| rng.below(VOCAB) as i64).collect();
            (prompt, NEW_TOKENS[i % NEW_TOKENS.len()])
        })
        .collect()
}

fn argmax(v: &[f32]) -> i64 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i64
}

/// The PR 5 shape, re-expressed over the paged APIs: FIFO batches of
/// `BATCH`, admitted together, decoded in lock-step, and the next batch
/// waits until *every* member of the current one has finished. Returns
/// (total generated tokens, per-request latencies in seconds).
fn request_level_baseline(
    model: &BertLike,
    pool: &Arc<KvPagePool>,
    requests: &[(Vec<i64>, usize)],
) -> (u64, Vec<f64>) {
    let t0 = Instant::now();
    let mut generated = 0u64;
    let mut latencies = vec![0.0f64; requests.len()];
    for (chunk_idx, chunk) in requests.chunks(BATCH).enumerate() {
        // prefill every member of the batch; prefill samples token 1
        let mut caches: Vec<PagedKvCache> = Vec::with_capacity(chunk.len());
        let mut last: Vec<i64> = Vec::with_capacity(chunk.len());
        let mut left: Vec<usize> = Vec::with_capacity(chunk.len());
        for (prompt, max_new) in chunk {
            let mut cache = PagedKvCache::new(Arc::clone(pool));
            cache.reserve(prompt.len() + max_new).expect("baseline pool sized for one batch");
            let ids = Tensor::from_slice(prompt, [1, prompt.len()]);
            let logits = no_grad(|| model.logits_paged(&ids, &mut cache)).tensor();
            let l = logits.dim(1);
            let row: Vec<f32> = logits.narrow(1, l - 1, 1).to_vec();
            caches.push(cache);
            last.push(argmax(&row));
            left.push(*max_new);
        }
        for (slot, l) in left.iter_mut().enumerate() {
            if *l > 0 {
                generated += 1;
                *l -= 1;
            }
            if *l == 0 {
                latencies[chunk_idx * BATCH + slot] = t0.elapsed().as_secs_f64();
            }
        }
        // lock-step decode; finished members leave the forward but their
        // slots stay blocked until the whole batch drains
        while left.iter().any(|&l| l > 0) {
            let mut ids = Vec::new();
            let mut rows = Vec::new();
            for (slot, &l) in left.iter().enumerate() {
                if l > 0 {
                    ids.push(last[slot]);
                    rows.push(slot);
                }
            }
            let step = Tensor::from_slice(&ids, [ids.len(), 1]);
            let mut refs: Vec<&mut PagedKvCache> = Vec::with_capacity(rows.len());
            let mut rest: &mut [PagedKvCache] = &mut caches;
            let mut consumed = 0usize;
            for &slot in &rows {
                let (_, tail) = rest.split_at_mut(slot - consumed);
                let (head, tail) = tail.split_at_mut(1);
                refs.push(&mut head[0]);
                rest = tail;
                consumed = slot + 1;
            }
            let logits = no_grad(|| model.logits_decode_batch(&step, &mut refs)).tensor();
            let v = logits.dims()[2];
            let flat = logits.to_vec();
            for (k, &slot) in rows.iter().enumerate() {
                last[slot] = argmax(&flat[k * v..(k + 1) * v]);
                generated += 1;
                left[slot] -= 1;
                if left[slot] == 0 {
                    latencies[chunk_idx * BATCH + slot] = t0.elapsed().as_secs_f64();
                }
            }
        }
        drop(caches); // release the batch's pages before the next admission
    }
    (generated, latencies)
}

fn p99(latencies: &[f64]) -> f64 {
    let mut sorted = latencies.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((sorted.len() as f64) * 0.99).ceil() as usize;
    sorted[idx.clamp(1, sorted.len()) - 1]
}

fn main() {
    flashlight::util::rng::seed(42);
    let model = Arc::new(BertLike::new(VOCAB, 64, 4, 2, PROMPT + 32 + 8));
    let mut rng = Rng::new(7);
    let requests = mixed_requests(&mut rng);
    let total_budget: u64 = requests.iter().map(|(_, n)| *n as u64).sum();
    // both policies get the same pool geometry: BATCH concurrent
    // worst-case reservations
    let pages_per_req = (PROMPT + 32).div_ceil(PAGE_TOKENS);
    let pool_pages = BATCH * pages_per_req;
    let mut records = Vec::new();

    // ---- request-level baseline (head-of-line blocking) -------------------
    let pool = KvPagePool::new(model.kv_pool_config(PAGE_TOKENS, pool_pages));
    let t0 = Instant::now();
    let (gen_tokens, latencies) = request_level_baseline(&model, &pool, &requests);
    let static_secs = t0.elapsed().as_secs_f64();
    assert_eq!(gen_tokens, total_budget, "baseline must decode every budgeted token");
    assert_eq!(pool.stats().leased_pages, 0, "baseline must drain the pool");
    let static_tps = gen_tokens as f64 / static_secs;
    let static_p99_us = p99(&latencies) * 1e6;
    let mut row = BenchRecord::new(
        "serve_request_level_batch4",
        static_secs * 1e9 / gen_tokens as f64,
        "cpu",
    );
    row.extras.push(("goodput_tokens_per_sec", static_tps));
    row.extras.push(("latency_p99_us", static_p99_us));
    row.extras.push(("requests", REQUESTS as f64));
    row.extras.push(("generated_tokens", gen_tokens as f64));
    records.push(row);

    // ---- continuous scheduler over the same pool geometry ------------------
    let cfg = ContinuousConfig {
        max_active: BATCH,
        page_tokens: PAGE_TOKENS,
        pool_pages: Some(pool_pages),
        ..Default::default()
    };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();
    let t0 = Instant::now();
    let handles: Vec<_> = requests
        .iter()
        .map(|(prompt, max_new)| {
            let opts = GenerateOptions {
                max_new_tokens: *max_new,
                sampling: Sampling::Greedy,
                seed: 0,
                ..Default::default()
            };
            batcher.submit(prompt, &opts)
        })
        .collect();
    for h in handles {
        h.wait().unwrap();
    }
    let cont_secs = t0.elapsed().as_secs_f64();
    let stats = batcher.stats();
    batcher.shutdown();
    assert_eq!(stats.generated_tokens, total_budget, "scheduler must decode every token");
    assert_eq!(stats.pool.leased_pages, 0, "scheduler must drain the pool");
    let cont_tps = stats.generated_tokens as f64 / cont_secs;
    let mut row = BenchRecord::new(
        "serve_continuous_batch4",
        cont_secs * 1e9 / stats.generated_tokens as f64,
        "cpu",
    );
    row.extras.push(("goodput_tokens_per_sec", cont_tps));
    row.extras.push(("busy_goodput_tokens_per_sec", stats.goodput_tps));
    row.extras.push(("latency_p99_us", stats.latency_p99_us));
    row.extras.push(("requests", REQUESTS as f64));
    row.extras.push(("generated_tokens", stats.generated_tokens as f64));
    row.extras.push(("mean_iteration_batch", stats.mean_iteration_batch));
    row.extras.push(("occupancy_mean", stats.occupancy_mean));
    row.extras.push(("backpressure_stalls", stats.backpressure_stalls as f64));
    row.extras.push(("speedup_vs_request_level", cont_tps / static_tps));
    row.extras.push(("p99_vs_request_level", stats.latency_p99_us / static_p99_us));
    records.push(row);
    println!(
        "mixed 32-request decode: request-level {static_tps:.1} tok/s (p99 {:.0}us), \
         continuous {cont_tps:.1} tok/s (p99 {:.0}us), {:.2}x goodput",
        static_p99_us,
        stats.latency_p99_us,
        cont_tps / static_tps
    );

    // ---- solo latency guard ------------------------------------------------
    let solo_prompt: Vec<i64> = (0..PROMPT).map(|i| (i * 5 % VOCAB) as i64).collect();
    let solo_opts = GenerateOptions {
        max_new_tokens: 32,
        sampling: Sampling::Greedy,
        seed: 0,
        ..Default::default()
    };
    let t0 = Instant::now();
    let direct = generate(&model, &solo_prompt, &solo_opts).unwrap();
    let direct_secs = t0.elapsed().as_secs_f64();
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();
    let t0 = Instant::now();
    let scheduled = batcher.generate(&solo_prompt, &solo_opts).unwrap();
    let sched_secs = t0.elapsed().as_secs_f64();
    batcher.shutdown();
    assert_eq!(scheduled.tokens, direct.tokens, "solo paths must agree bitwise");
    let mut row = BenchRecord::new("serve_decode_solo_direct", direct_secs * 1e9 / 32.0, "cpu");
    row.extras.push(("total_secs", direct_secs));
    records.push(row);
    let mut row = BenchRecord::new("serve_decode_solo_continuous", sched_secs * 1e9 / 32.0, "cpu");
    row.extras.push(("total_secs", sched_secs));
    row.extras.push(("overhead_vs_direct", sched_secs / direct_secs));
    records.push(row);
    println!(
        "solo decode: direct {direct_secs:.3}s vs continuous {sched_secs:.3}s \
         ({:.2}x)",
        sched_secs / direct_secs
    );

    write_bench_json("BENCH_PR6.json", &records);
}
