//! Observability overhead snapshot -> BENCH_PR10.json.
//!
//! The obs layer's contract is that *disabled* instrumentation costs one
//! relaxed atomic load per checkpoint. Two measurements prove it on the
//! serve-decode workload (the hottest instrumented loop in the repo):
//!
//! - **decode iteration latency, obs off vs on**: the compiled `[B, 1]`
//!   decode step ([`CompiledDecodeStep`]) timed with recording disabled
//!   and then enabled — the directly-observed overhead fraction;
//! - **checkpoint microbench**: the cost of one disabled
//!   [`flashlight::obs::span`] call (the per-checkpoint price every
//!   instrumented site pays while recording is off), multiplied by a
//!   generous bound on checkpoints per decode iteration and divided by
//!   the iteration time. This `computed_disabled_overhead_frac` is the
//!   value CI guards (< 1%): unlike the off-vs-on A/B, it cannot go
//!   negative under scheduler noise, so the guard is deterministic.
//!
//! Run: `cargo bench --bench obs_overhead`

use std::sync::Arc;
use std::time::Instant;

use flashlight::autograd::no_grad;
use flashlight::memory::KvPagePool;
use flashlight::models::BertLike;
use flashlight::nn::PagedKvCache;
use flashlight::serve::CompiledDecodeStep;
use flashlight::testutil::{write_bench_json, BenchRecord};
use flashlight::Tensor;

const VOCAB: usize = 64;
const PREFILL: usize = 16;
const STEPS: usize = 24;
const REPS: usize = 5;
const BATCH: usize = 4;
/// Generous upper bound on obs checkpoints one decode iteration crosses
/// (iteration span + per-segment executor checks + stats publication —
/// counted by hand it is under 16; doubled for slack).
const SPAN_SITES_PER_ITER: f64 = 32.0;
const MICRO_CALLS: usize = 1_000_000;

/// Fresh per-request caches, each prefilled with `PREFILL` tokens.
fn fresh_caches(model: &BertLike) -> Vec<PagedKvCache> {
    let page_tokens = 8;
    let pages = BATCH * (PREFILL + STEPS).div_ceil(page_tokens);
    let pool = KvPagePool::new(model.kv_pool_config(page_tokens, pages));
    (0..BATCH)
        .map(|r| {
            let mut cache = PagedKvCache::new(Arc::clone(&pool));
            cache.reserve(PREFILL + STEPS).expect("bench pool sized exactly");
            let prompt: Vec<i64> =
                (0..PREFILL).map(|j| ((r * 13 + j * 5) % VOCAB) as i64).collect();
            let ids = Tensor::from_slice(&prompt, [1, PREFILL]);
            no_grad(|| model.logits_paged(&ids, &mut cache));
            cache
        })
        .collect()
}

/// One timed rep of `STEPS` compiled decode iterations; returns seconds.
fn decode_rep(model: &BertLike, step: &CompiledDecodeStep) -> f64 {
    let mut caches = fresh_caches(model);
    let t0 = Instant::now();
    for t in 0..STEPS {
        let tokens: Vec<i64> = (0..BATCH).map(|r| ((r * 7 + t * 3) % VOCAB) as i64).collect();
        let mut refs: Vec<&mut PagedKvCache> = caches.iter_mut().collect();
        let logits = no_grad(|| step.step(model, &tokens, &mut refs))
            .expect("compiled step")
            .expect("bench batch size has a bucket");
        std::hint::black_box(&logits);
    }
    t0.elapsed().as_secs_f64()
}

/// Best-of-`REPS` nanoseconds per decode iteration at the current obs
/// switch setting.
fn best_iter_ns(model: &BertLike, step: &CompiledDecodeStep) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..REPS {
        best = best.min(decode_rep(model, step));
        // keep the rings bounded between enabled-mode reps
        flashlight::obs::reset();
    }
    best * 1e9 / STEPS as f64
}

/// Nanoseconds per `span()` call at the current switch setting.
fn span_ns() -> f64 {
    let t0 = Instant::now();
    for _ in 0..MICRO_CALLS {
        let s = flashlight::obs::span("obs.bench.checkpoint");
        std::hint::black_box(&s);
    }
    let ns = t0.elapsed().as_secs_f64() * 1e9 / MICRO_CALLS as f64;
    flashlight::obs::reset();
    ns
}

fn main() {
    flashlight::util::rng::seed(42);
    let model = BertLike::new(VOCAB, 64, 4, 2, PREFILL + STEPS + 8);
    let step = CompiledDecodeStep::compile(&model, &[BATCH]).expect("decode bucket compiles");

    // ---- serve-decode A/B: recording off vs on -----------------------------
    flashlight::obs::set_enabled(false);
    let disabled_ns = best_iter_ns(&model, &step);
    flashlight::obs::set_enabled(true);
    let enabled_ns = best_iter_ns(&model, &step);
    flashlight::obs::set_enabled(false);
    let overhead_frac = (enabled_ns - disabled_ns) / disabled_ns;

    // ---- checkpoint microbench ---------------------------------------------
    let disabled_span_ns = span_ns();
    flashlight::obs::set_enabled(true);
    let enabled_span_ns = span_ns();
    flashlight::obs::set_enabled(false);
    // the deterministic guard value: what SPAN_SITES_PER_ITER disabled
    // checkpoints cost relative to one whole decode iteration
    let computed_disabled_overhead_frac = disabled_span_ns * SPAN_SITES_PER_ITER / disabled_ns;

    let mut records = Vec::new();
    let mut row = BenchRecord::new("obs_decode_iter_disabled", disabled_ns, "cpu");
    row.extras.push(("batch", BATCH as f64));
    row.extras.push(("steps", STEPS as f64));
    records.push(row);
    let mut row = BenchRecord::new("obs_decode_iter_enabled", enabled_ns, "cpu");
    row.extras.push(("batch", BATCH as f64));
    row.extras.push(("overhead_frac", overhead_frac));
    records.push(row);
    let mut row = BenchRecord::new("obs_disabled_span", disabled_span_ns, "cpu");
    row.extras.push(("span_sites_per_iter", SPAN_SITES_PER_ITER));
    row.extras.push(("computed_disabled_overhead_frac", computed_disabled_overhead_frac));
    records.push(row);
    let mut row = BenchRecord::new("obs_enabled_span", enabled_span_ns, "cpu");
    row.extras.push(("spans_per_sec", 1e9 / enabled_span_ns.max(1e-9)));
    records.push(row);
    write_bench_json("BENCH_PR10.json", &records);

    println!(
        "decode iter: disabled {:.1}us, enabled {:.1}us ({:+.2}% observed)",
        disabled_ns / 1e3,
        enabled_ns / 1e3,
        overhead_frac * 100.0
    );
    println!(
        "checkpoint: disabled {disabled_span_ns:.2}ns/span, enabled {enabled_span_ns:.1}ns/span; \
         computed disabled overhead {:.4}% of an iteration (CI bound: 1%)",
        computed_disabled_overhead_frac * 100.0
    );
}
