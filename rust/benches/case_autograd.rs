//! §5.2.1 case-study bench: autograd over large, sparse, decoder-style
//! lattices (the differentiable-beam-search workload — "graphs contained
//! millions of nodes ... small operator overhead per node ... only sparse
//! components of the graph were required").
//!
//! Builds a token lattice of scalar add/log nodes where only a fraction of
//! branches carry probability mass, then ablates the §5.2.1 autograd
//! customizations: zero-gradient pruning on/off and node-lifetime release.
//!
//! Run: `cargo bench --bench case_autograd [width] [depth]`

use flashlight::autograd::{ops, BackwardOpts, Variable};
use flashlight::tensor::Tensor;
use flashlight::util::timing::Timer;

/// Build a lattice: `depth` layers of `width` nodes; each node combines two
/// parents with add/log ops. `live_frac` of the lattice carries signal —
/// the rest is multiplied by exact zeros (pruned branches of a beam).
fn build_lattice(width: usize, depth: usize, live_frac: f64) -> (Vec<Variable>, Variable) {
    let leaves: Vec<Variable> =
        (0..width).map(|i| Variable::param(Tensor::full([1], 0.1 + i as f64 * 0.01, flashlight::tensor::DType::F32))).collect();
    let zero = Variable::constant(Tensor::zeros([1]));
    let mut layer = leaves.clone();
    let live = ((width as f64) * live_frac).max(1.0) as usize;
    for d in 0..depth {
        let mut next = Vec::with_capacity(width);
        for i in 0..width {
            let a = &layer[i];
            let b = &layer[(i + 1) % width];
            let combined = ops::add(a, b);
            let node = if i < live {
                // value/gradient-preserving combine (the b-contributions
                // cancel): deep lattices keep O(1) gradients instead of
                // underflowing to exact zeros, which would (correctly!)
                // trigger pruning and defeat the ablation
                ops::sub(&combined, b)
            } else {
                // dead branch: killed by an exact zero (beam pruned it)
                ops::mul(&ops::add_scalar(&combined, 1.0 + d as f64), &zero)
            };
            next.push(node);
        }
        layer = next;
    }
    // final scoring layer: the log of the accumulated path mass
    let scored: Vec<Variable> =
        layer.iter().map(|n| ops::log(&ops::add_scalar(n, 1.5))).collect();
    let refs: Vec<&Variable> = scored.iter().collect();
    let root = ops::sum(&ops::concat(&refs, 0), &[], false);
    (leaves, root)
}

fn run(width: usize, depth: usize, prune: bool) -> (f64, usize, usize) {
    let (leaves, root) = build_lattice(width, depth, 0.125);
    let t = Timer::start();
    let stats = root.backward_with(&BackwardOpts {
        prune_zero_grads: prune,
        retain_graph: false,
    });
    let secs = t.secs();
    // gradient sanity: live leaves got gradients
    assert!(leaves[0].grad().is_some());
    (secs, stats.nodes_visited, stats.nodes_pruned)
}

fn main() {
    let width: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let depth: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(400);
    let total_nodes = width * depth * 2;
    println!("== §5.2.1: sparse decoder-lattice autograd ({total_nodes} ops) ==");
    println!("{:<22} {:>10} {:>12} {:>10}", "CONFIG", "time (s)", "visited", "pruned");

    // warmup
    let _ = run(width / 2, depth / 2, false);

    let (t_off, v_off, _) = run(width, depth, false);
    println!("{:<22} {:>10.3} {:>12} {:>10}", "pruning off", t_off, v_off, 0);
    let (t_on, v_on, pruned) = run(width, depth, true);
    println!("{:<22} {:>10.3} {:>12} {:>10}", "pruning on", t_on, v_on, pruned);

    let speedup = t_off / t_on;
    let skipped = v_off.saturating_sub(v_on);
    println!(
        "\npruning speedup: {speedup:.2}x ({pruned} zero-gradient cut points, \
         {skipped} downstream nodes never visited)"
    );
    assert!(
        skipped > total_nodes / 4,
        "expected substantial pruning: {skipped} skipped of {total_nodes}"
    );

    // node-lifetime ablation: releasing graphs frees the lattice eagerly
    let (_, root) = build_lattice(width, depth / 4, 0.125);
    let t = Timer::start();
    root.backward_with(&BackwardOpts { retain_graph: true, prune_zero_grads: false });
    let retain = t.secs();
    let (_, root2) = build_lattice(width, depth / 4, 0.125);
    let t = Timer::start();
    root2.backward_with(&BackwardOpts { retain_graph: false, prune_zero_grads: false });
    let release = t.secs();
    println!(
        "node-lifetime: backward w/ retain {:.3}s vs release {:.3}s (release also frees {} nodes)",
        retain,
        release,
        width * depth / 4
    );
    println!("case_autograd OK");
}
