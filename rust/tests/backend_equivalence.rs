//! Property tests across tensor backends: the eager CPU backend, the
//! deferred lazy backend, and (when artifacts exist) the AOT XLA backend
//! must agree on every composed expression — Figure 2's guarantee that the
//! computation mode is an implementation detail behind the API.

use std::sync::Arc;

use flashlight::tensor::cpu::CpuBackend;
use flashlight::tensor::lazy::LazyBackend;
use flashlight::tensor::{BackendGuard, Op, Tensor, TensorBackend};
use flashlight::testutil::prop;
use flashlight::util::rng::Rng;

/// Random element-wise expression over two operands.
fn random_expr(rng: &mut Rng, a: &Tensor, b: &Tensor) -> Tensor {
    let mut cur = a.clone();
    let depth = 2 + rng.below(5);
    for _ in 0..depth {
        cur = match rng.below(7) {
            0 => cur.add(b),
            1 => cur.sub(b),
            2 => cur.mul(b),
            3 => cur.tanh(),
            4 => cur.abs().add_scalar(0.1).sqrt(),
            5 => cur.neg(),
            _ => cur.maximum(b),
        };
    }
    cur
}

#[test]
fn prop_lazy_matches_eager_on_random_expressions() {
    prop::run(
        "lazy-vs-eager",
        30,
        |rng| {
            let shape = prop::random_shape(rng, 3, 6);
            let n: usize = shape.iter().product();
            let a = prop::random_vec(rng, n, 2.0);
            let b = prop::random_vec(rng, n, 2.0);
            let ops_seed = rng.next_u64();
            (shape, a, b, ops_seed)
        },
        |(shape, av, bv, ops_seed)| {
            let eager = {
                let a = Tensor::from_slice(av, shape.clone());
                let b = Tensor::from_slice(bv, shape.clone());
                let mut r = Rng::new(*ops_seed);
                random_expr(&mut r, &a, &b).to_vec()
            };
            let lazy = {
                let _g = BackendGuard::install(LazyBackend::shared());
                let a = Tensor::from_slice(av, shape.clone());
                let b = Tensor::from_slice(bv, shape.clone());
                let mut r = Rng::new(*ops_seed);
                random_expr(&mut r, &a, &b).to_vec()
            };
            for (i, (e, l)) in eager.iter().zip(&lazy).enumerate() {
                if (e - l).abs() > 1e-4 * (1.0 + e.abs()) {
                    return Err(format!("elem {i}: eager {e} vs lazy {l}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_broadcast_semantics_match_across_backends() {
    prop::run(
        "broadcast-lazy-vs-eager",
        30,
        |rng| {
            let shape = prop::random_shape(rng, 3, 5);
            let bshape = prop::broadcastable_shape(rng, &shape);
            let n: usize = shape.iter().product();
            let m: usize = bshape.iter().product();
            (shape, bshape, prop::random_vec(rng, n, 3.0), prop::random_vec(rng, m, 3.0))
        },
        |(shape, bshape, av, bv)| {
            let run = |lazy: bool| -> Vec<f32> {
                let _g = lazy.then(|| BackendGuard::install(LazyBackend::shared()));
                let a = Tensor::from_slice(av, shape.clone());
                let b = Tensor::from_slice(bv, bshape.clone());
                a.add(&b).mul(&b).to_vec()
            };
            let (e, l) = (run(false), run(true));
            if e.len() != l.len() {
                return Err(format!("length {} vs {}", e.len(), l.len()));
            }
            for (i, (x, y)) in e.iter().zip(&l).enumerate() {
                if (x - y).abs() > 1e-4 {
                    return Err(format!("elem {i}: {x} vs {y}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_matmul_associates_with_identity() {
    prop::run(
        "matmul-identity",
        20,
        |rng| {
            let m = 1 + rng.below(8);
            let k = 1 + rng.below(8);
            (m, k, prop::random_vec(rng, m * k, 2.0))
        },
        |(m, k, data)| {
            let a = Tensor::from_slice(data, vec![*m, *k]);
            let i = Tensor::eye(*k, flashlight::tensor::DType::F32);
            let out = a.matmul(&i).to_vec();
            for (x, y) in out.iter().zip(data) {
                if (x - y).abs() > 1e-5 {
                    return Err(format!("{x} != {y}"));
                }
            }
            Ok(())
        },
    );
}

/// Lazy-vs-CPU through the *IR surface*: the same reified program,
/// executed op by op via `dispatch` on both backends, must agree — the
/// deferral/fusion machinery is an implementation detail behind the
/// choke point.
#[test]
fn lazy_matches_cpu_through_dispatch() {
    let cpu = CpuBackend::shared();
    let lazy = LazyBackend::shared();
    // a mixed program: deferred ops (matmul/add/tanh/mul) and an eager
    // fallback (sum) that forces the pending graph
    let program = [
        Op::Matmul,
        Op::Add,
        Op::Tanh,
        Op::Mul,
        Op::Abs,
        Op::Sqrt,
        Op::Sum { axes: vec![1], keepdims: false },
    ];
    let av: Vec<f32> = (0..16).map(|i| 0.3 * i as f32 - 2.0).collect();
    let bv: Vec<f32> = (0..16).map(|i| 1.5 - 0.2 * i as f32).collect();

    let run = |be: &dyn TensorBackend| -> Vec<f32> {
        let a = be.from_host(flashlight::tensor::HostBuffer::F32(av.clone()), [4, 4].into());
        let b = be.from_host(flashlight::tensor::HostBuffer::F32(bv.clone()), [4, 4].into());
        let mut cur = a;
        for op in &program {
            let inputs: Vec<&Tensor> = match op.arity() {
                Some(2) => vec![&cur, &b],
                _ => vec![&cur],
            };
            cur = be.dispatch(op, &inputs).unwrap_or_else(|e| panic!("{}: {e}", op.name()));
        }
        cur.to_vec()
    };

    let (eager, deferred) = (run(cpu.as_ref()), run(lazy.as_ref()));
    assert_eq!(eager.len(), deferred.len());
    for (i, (e, l)) in eager.iter().zip(&deferred).enumerate() {
        assert!(
            (e - l).abs() <= 1e-4 * (1.0 + e.abs()),
            "elem {i}: cpu {e} vs lazy {l}"
        );
    }
}

/// The lazy-vs-cpu suite, through the *new pipeline*: capture the same
/// random expressions with `TraceBackend`, compile them with the full
/// pass pipeline, and require the optimized execution to be
/// bit-identical to replaying the unoptimized trace. (The lazy backend
/// itself materializes through this pipeline too, so the props above
/// already exercise it end to end — this pins the compiler directly.)
#[test]
fn prop_compiled_pipeline_matches_trace_replay() {
    use flashlight::tensor::graph::{compile, CompileOptions};
    use flashlight::tensor::{DType, HostBuffer, Shape, TraceBackend, ValueRef};

    /// `random_expr`, but over explicit backend calls so the capture is
    /// immune to concurrent tests swapping the process-global default.
    fn random_expr_on(
        be: &dyn TensorBackend,
        rng: &mut Rng,
        a: &Tensor,
        b: &Tensor,
    ) -> Tensor {
        let mut cur = be.copy(a);
        let depth = 2 + rng.below(5);
        for _ in 0..depth {
            cur = match rng.below(7) {
                0 => be.add(&cur, b),
                1 => be.sub(&cur, b),
                2 => be.mul(&cur, b),
                3 => be.tanh(&cur),
                4 => {
                    let eps = be.full(&Shape::scalar(), 0.1, DType::F32);
                    be.sqrt(&be.add(&be.abs(&cur), &eps))
                }
                5 => be.neg(&cur),
                _ => be.maximum(&cur, b),
            };
        }
        cur
    }

    prop::run(
        "compiled-vs-replay",
        30,
        |rng| {
            let shape = prop::random_shape(rng, 3, 6);
            let n: usize = shape.iter().product();
            let a = prop::random_vec(rng, n, 2.0);
            let b = prop::random_vec(rng, n, 2.0);
            let ops_seed = rng.next_u64();
            (shape, a, b, ops_seed)
        },
        |(shape, av, bv, ops_seed)| {
            let be = TraceBackend::over_cpu_default();
            let traced = {
                let a = be.from_host(HostBuffer::F32(av.clone()), shape.clone().into());
                let b = be.from_host(HostBuffer::F32(bv.clone()), shape.clone().into());
                let mut r = Rng::new(*ops_seed);
                random_expr_on(be.as_ref(), &mut r, &a, &b).to_vec()
            };
            let program = be.interposer().program();
            if program.is_empty() {
                return Err("trace captured nothing".into());
            }
            let root = ValueRef::Out(program.len() - 1);
            let compiled = compile(&program, &[root], &CompileOptions::default())
                .map_err(|e| e.to_string())?;
            let outs = compiled
                .run(CpuBackend::shared().as_ref())
                .map_err(|e| e.to_string())?;
            let got = outs[0].to_vec();
            if got.len() != traced.len() {
                return Err(format!("length {} vs {}", got.len(), traced.len()));
            }
            for (i, (t, g)) in traced.iter().zip(&got).enumerate() {
                if t.to_bits() != g.to_bits() {
                    return Err(format!(
                        "elem {i} not bit-identical: traced {t} vs compiled {g} (pipeline: {})",
                        compiled.report.summary()
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Diamond-heavy sharing through the lazy backend's new pipeline path:
/// repeated self-adds double per layer without exponential walks.
#[test]
fn lazy_pipeline_diamonds_match_eager() {
    let depth = 24; // 2^24 stays exactly representable in f32
    let eager = {
        let mut x = Tensor::from_slice(&[1.0f32, 0.5], [2]);
        for _ in 0..depth {
            x = x.add(&x);
        }
        x.to_vec()
    };
    let lazy = {
        // explicit dispatch on the lazy backend: immune to concurrent
        // tests swapping the process-global default
        let be = LazyBackend::shared();
        let mut x = be.from_host(
            flashlight::tensor::HostBuffer::F32(vec![1.0, 0.5]),
            [2].into(),
        );
        for _ in 0..depth {
            x = be.add(&x, &x);
        }
        assert_eq!(flashlight::tensor::lazy::pending_ops(&x), depth);
        x.to_vec()
    };
    assert_eq!(eager, lazy);
}

#[test]
fn xla_backend_matches_cpu_when_available() {
    let Some(xla) = flashlight::tensor::xla_backend::XlaBackend::from_global_runtime() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let xla: Arc<dyn TensorBackend> = xla;
    flashlight::util::rng::seed(77);
    for (m, k, n) in [(32usize, 256usize, 256usize), (64, 256, 256)] {
        let a = Tensor::rand([m, k], -1.0, 1.0);
        let b = Tensor::rand([k, n], -1.0, 1.0);
        let cpu_out = a.matmul(&b);
        let xla_out = xla.matmul(&a, &b);
        let d = cpu_out.max_abs_diff(&xla_out).unwrap();
        assert!(d < 1e-3, "{m}x{k}x{n}: {d}");
    }
}
