//! Cross-module integration tests: training convergence end to end,
//! config -> trainer wiring, checkpoint round trips through real models,
//! PJRT artifact execution against the composed CPU graph, and
//! distributed-vs-sequential equivalence.

use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::coordinator::{load_params, save_params, train_classifier, TrainConfig};
use flashlight::data::TransformDataset;
use flashlight::models::{by_name, mlp, BertLike};
use flashlight::nn::Module;
use flashlight::pkg::vision::synthetic_image_classification;
use flashlight::runtime::PjrtRuntime;
use flashlight::tensor::{DType, Tensor};

#[test]
fn full_training_pipeline_converges() {
    let ds = synthetic_image_classification(96, 1, 8, 3, 5);
    let flat = Arc::new(TransformDataset::new(ds, |mut s| {
        let n = s[0].numel();
        s[0] = s[0].reshape(&[1, n as isize]);
        s
    }));
    let mut model = mlp(&[64, 48, 3]);
    let cfg = TrainConfig { steps: 80, batch_size: 12, lr: 3e-3, ..Default::default() };
    let report = train_classifier(&mut model, flat, &cfg, |_, _| {}).unwrap();
    assert!(
        report.final_loss < report.loss_curve[0].1,
        "loss did not decrease: {:?}",
        report.loss_curve
    );
    assert!(report.final_loss < 0.5, "final loss {}", report.final_loss);
}

#[test]
fn checkpoint_roundtrip_through_resnet() {
    let dir = std::env::temp_dir().join("fl_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resnet.ckpt");
    let (model_a, _) = by_name("resnet").unwrap();
    save_params(&path, &model_a.params()).unwrap();
    let (model_b, _) = by_name("resnet").unwrap();
    load_params(&path, &model_b.params()).unwrap();
    // identical outputs after loading
    let x = Variable::constant(Tensor::rand([1, 3, 32, 32], -1.0, 1.0));
    // eval mode so batchnorm uses (identical) running stats
    let mut ma = model_a;
    let mut mb = model_b;
    ma.set_train(false);
    mb.set_train(false);
    let ya = ma.forward(&x).tensor();
    let yb = mb.forward(&x).tensor();
    assert!(ya.allclose(&yb, 1e-6, 1e-6));
}

#[test]
fn pjrt_transformer_block_matches_rust_composition() {
    let Some(rt) = PjrtRuntime::global() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    flashlight::util::rng::seed(101);
    let (b, l, d, heads, mlp_d) = (4usize, 32usize, 256usize, 4usize, 1024usize);
    // weights (no biases on attention projections, matching the artifact)
    let x = Tensor::rand([b, l, d], -0.5, 0.5);
    let wq = Tensor::rand([d, d], -0.05, 0.05);
    let wk = Tensor::rand([d, d], -0.05, 0.05);
    let wv = Tensor::rand([d, d], -0.05, 0.05);
    let wo = Tensor::rand([d, d], -0.05, 0.05);
    let w1 = Tensor::rand([d, mlp_d], -0.05, 0.05);
    let b1 = Tensor::rand([mlp_d], -0.05, 0.05);
    let w2 = Tensor::rand([mlp_d, d], -0.05, 0.05);
    let b2 = Tensor::rand([d], -0.05, 0.05);
    let ones = Tensor::ones([d]);
    let zeros = Tensor::zeros([d]);

    let got = rt
        .run(
            "transformer_block",
            &[&x, &wq, &wk, &wv, &wo, &w1, &b1, &w2, &b2, &ones, &zeros, &ones, &zeros],
        )
        .unwrap();

    // compose the same block in Rust from primitives
    let layernorm = |t: &Tensor| -> Tensor {
        let mu = t.mean(&[-1], true);
        let c = t.sub(&mu);
        let var = c.mul(&c).mean(&[-1], true);
        c.div(&var.add_scalar(1e-5).sqrt())
    };
    let h = layernorm(&x);
    let split = |t: &Tensor| -> Tensor {
        let hd = d / heads;
        t.reshape(&[b as isize, l as isize, heads as isize, hd as isize])
            .transpose(&[0, 2, 1, 3])
            .reshape(&[(b * heads) as isize, l as isize, hd as isize])
    };
    let q = split(&h.matmul(&wq));
    let k = split(&h.matmul(&wk));
    let v = split(&h.matmul(&wv));
    let hd = d / heads;
    let scale = 1.0 / (hd as f64).sqrt();
    let ctx = q.matmul(&k.t()).mul_scalar(scale).softmax(-1).matmul(&v);
    let ctx = ctx
        .reshape(&[b as isize, heads as isize, l as isize, hd as isize])
        .transpose(&[0, 2, 1, 3])
        .reshape(&[b as isize, l as isize, d as isize]);
    let x1 = x.add(&ctx.matmul(&wo));
    let h2 = layernorm(&x1);
    let mlp_out = h2
        .reshape(&[(b * l) as isize, d as isize])
        .matmul(&w1)
        .add(&b1)
        .gelu()
        .matmul(&w2)
        .add(&b2);
    let want = x1.add(&mlp_out.reshape(&[b as isize, l as isize, d as isize]));

    let diff = got.max_abs_diff(&want).unwrap();
    assert!(diff < 5e-4, "AOT transformer block vs composed graph: {diff}");
}

#[test]
fn bert_lm_learns_structure_quickly() {
    flashlight::util::rng::seed(5);
    // deterministic cycle corpus: the model should approach zero loss
    let toks: Vec<usize> = (0..400).map(|i| i % 7 + 3).collect();
    let ds = Arc::new(flashlight::pkg::text::AutoregressiveLmDataset::new(toks, 14, 3));
    let model = BertLike::new(16, 32, 2, 1, 15);
    let cfg = TrainConfig { steps: 40, batch_size: 8, lr: 5e-3, log_every: 10, ..Default::default() };
    let report = flashlight::coordinator::train_lm(&model, ds, &cfg, |_, _| {}).unwrap();
    assert!(report.final_loss < 1.0, "cycle LM loss {}", report.final_loss);
}

#[test]
fn gradients_flow_through_every_table3_model_batchwise() {
    for name in ["alexnet", "vit"] {
        let (model, spec) = by_name(name).unwrap();
        let x = match spec.image_input {
            Some((c, h, w)) => Tensor::rand([spec.batch, c, h, w], -1.0, 1.0),
            None => Tensor::rand([spec.batch, spec.seq_len], 0.0, spec.vocab as f64)
                .astype(DType::I64),
        };
        let out = model.forward(&Variable::constant(x));
        flashlight::autograd::ops::sum(&out, &[], false).backward();
        let missing =
            model.params().iter().filter(|p| p.grad().is_none()).count();
        assert_eq!(missing, 0, "{name}: {missing} params without grads");
    }
}
