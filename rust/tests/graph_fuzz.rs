//! Differential fuzzing for the graph compiler: hundreds of random `Op`
//! programs (random shapes, dtypes, shared operands, dead outputs) are
//! executed twice on the reference CPU backend — once by replaying the
//! unoptimized trace, once through the full optimization pipeline — and
//! every requested output must be **bit-identical**.
//!
//! The static verifier rides along as an oracle: every generated
//! program's source graph must pass `verify::source_spec`, and every
//! compiled program must verify with **zero diagnostics** against that
//! spec — the false-positive half of the mutation-kill contract proven
//! in `rust/tests/graph_verify.rs`. (CI additionally runs this suite
//! with `FL_VERIFY=1`, re-verifying inside `compile` after every pass.)
//!
//! Knobs (see docs/ARCHITECTURE.md, "Testing & fuzzing guide"):
//!
//! - `GRAPH_FUZZ_CASES`: cases per configuration (default 500 for the
//!   full pipeline, a fifth of that per single-pass run). CI's `fuzz`
//!   job raises this.
//! - `GRAPH_FUZZ_SEED` (decimal or 0x-hex): pins case 0's generation
//!   seed (later cases derive from it). Every failure panic prints the
//!   *case* seed; re-running with that value as `GRAPH_FUZZ_SEED` and
//!   `GRAPH_FUZZ_CASES=1` replays exactly the failing program.

use flashlight::tensor::cpu::CpuBackend;
use flashlight::tensor::graph::{compile, verify, CompileOptions, Graph};
use flashlight::tensor::trace::{TraceInstr, TraceProgram, ValueRef};
use flashlight::tensor::{DType, HostBuffer, Op, Tensor};
use flashlight::testutil::prop;
use flashlight::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `GRAPH_FUZZ_SEED`, if set (decimal or 0x-hex). A pinned seed is used
/// *directly* as case 0's generation seed, so the seed printed by a
/// failure panic replays that exact program as case 0.
fn env_seed() -> Option<u64> {
    match std::env::var("GRAPH_FUZZ_SEED") {
        Ok(v) => {
            let v = v.trim();
            let parsed = match v.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => v.parse().ok(),
            };
            Some(parsed.unwrap_or_else(|| panic!("unparseable GRAPH_FUZZ_SEED: {v}")))
        }
        Err(_) => None,
    }
}

/// A value the generator can wire into later ops, with the metadata the
/// generator tracks to keep programs well-formed.
#[derive(Clone)]
struct Value {
    r: ValueRef,
    shape: Vec<usize>,
    dtype: DType,
}

struct Builder {
    program: TraceProgram,
    pool: Vec<Value>,
}

impl Builder {
    fn push(&mut self, op: Op, inputs: Vec<ValueRef>, shape: Vec<usize>, dtype: DType) -> Value {
        let id = self.program.instrs.len();
        self.program.instrs.push(TraceInstr { op, inputs });
        let v = Value { r: ValueRef::Out(id), shape, dtype };
        self.pool.push(v.clone());
        v
    }

    fn fresh_f32(&mut self, rng: &mut Rng, shape: Vec<usize>) -> Value {
        let n: usize = shape.iter().product();
        let data = prop::random_vec(rng, n, 2.0);
        self.push(
            Op::FromHost { host: HostBuffer::F32(data), shape: shape.clone().into() },
            vec![],
            shape,
            DType::F32,
        )
    }

    fn pick(&self, rng: &mut Rng) -> Value {
        self.pool[rng.below(self.pool.len())].clone()
    }

    /// A pool value or fresh constant that broadcasts against `shape`.
    fn companion(&mut self, rng: &mut Rng, shape: &[usize]) -> Value {
        if rng.uniform() < 0.5 {
            let candidates: Vec<Value> = self
                .pool
                .iter()
                .filter(|v| broadcast(&v.shape, shape).is_some())
                .cloned()
                .collect();
            if !candidates.is_empty() {
                return candidates[rng.below(candidates.len())].clone();
            }
        }
        let bshape = prop::broadcastable_shape(rng, shape);
        self.fresh_f32(rng, bshape)
    }
}

/// NumPy broadcast of two shapes (None when incompatible).
fn broadcast(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let rank = a.len().max(b.len());
    let mut out = vec![0usize; rank];
    for i in 0..rank {
        let x = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let y = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if x == y || y == 1 {
            x
        } else if x == 1 {
            y
        } else {
            return None;
        };
    }
    Some(out)
}

fn reduce_shape(shape: &[usize], axes: &[usize], keepdims: bool) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, &d) in shape.iter().enumerate() {
        if axes.contains(&i) {
            if keepdims {
                out.push(1);
            }
        } else {
            out.push(d);
        }
    }
    out
}

/// Generate one random program plus its requested outputs. Random ops
/// (`rand_uniform`) are included as *dead* values only — they advance the
/// RNG stream and may not feed observable outputs, so DCE must keep them
/// without their (run-dependent) values being compared.
fn gen_program(rng: &mut Rng) -> (TraceProgram, Vec<ValueRef>) {
    let mut b = Builder { program: TraceProgram::default(), pool: Vec::new() };
    // seed operands: a couple of FromHost instrs and one pool constant
    for _ in 0..(2 + rng.below(2)) {
        let shape = prop::random_shape(rng, 3, 4);
        b.fresh_f32(rng, shape);
    }
    {
        let shape = prop::random_shape(rng, 2, 4);
        let n: usize = shape.iter().product();
        let c = ValueRef::Const(b.program.consts.len());
        b.program.consts.push(Tensor::from_slice(&prop::random_vec(rng, n, 2.0), shape.clone()));
        b.pool.push(Value { r: c, shape, dtype: DType::F32 });
    }

    let steps = 4 + rng.below(12);
    let mut tainted_rand = false;
    for _ in 0..steps {
        match rng.below(12) {
            // fusible + non-fusible unaries (float ops promote ints to f32)
            0 | 1 => {
                let x = b.pick(rng);
                let ops = [
                    Op::Neg,
                    Op::Abs,
                    Op::Sign,
                    Op::Exp,
                    Op::Log,
                    Op::Tanh,
                    Op::Sqrt,
                    Op::Clip { lo: -1.25, hi: 2.5 },
                    Op::Erf,
                    Op::Sin,
                    Op::Cos,
                    Op::Log1p,
                    Op::Rsqrt,
                    Op::Reciprocal,
                    Op::Floor,
                    Op::Round,
                ];
                let op = ops[rng.below(ops.len())].clone();
                let dtype = match &op {
                    Op::Neg | Op::Abs | Op::Sign | Op::Clip { .. } => x.dtype,
                    _ if x.dtype.is_float() => x.dtype,
                    _ => DType::F32,
                };
                b.push(op, vec![x.r], x.shape.clone(), dtype);
            }
            // binary arithmetic with broadcasting + dtype promotion
            2 | 3 | 4 => {
                let x = b.pick(rng);
                let y = b.companion(rng, &x.shape);
                let shape = broadcast(&x.shape, &y.shape).expect("companion must broadcast");
                let ops = [
                    Op::Add,
                    Op::Sub,
                    Op::Mul,
                    Op::Div,
                    Op::Minimum,
                    Op::Maximum,
                    Op::Pow,
                    Op::Rem,
                ];
                let op = ops[rng.below(ops.len())].clone();
                b.push(op, vec![x.r, y.r], shape, x.dtype.promote(y.dtype));
            }
            // comparisons -> Bool values in the pool
            5 => {
                let x = b.pick(rng);
                let y = b.companion(rng, &x.shape);
                let shape = broadcast(&x.shape, &y.shape).unwrap();
                let ops = [Op::Eq, Op::Lt, Op::Le, Op::Gt, Op::LogicalAnd, Op::LogicalOr];
                b.push(ops[rng.below(ops.len())].clone(), vec![x.r, y.r], shape, DType::Bool);
            }
            // reductions
            6 => {
                let x = b.pick(rng);
                let rank = x.shape.len();
                let mut axes: Vec<usize> = (0..rank).filter(|_| rng.uniform() < 0.5).collect();
                if axes.is_empty() {
                    axes = (0..rank).collect();
                }
                // keep rank >= 1 so every pool value has at least one axis
                let keepdims = rng.uniform() < 0.5 || axes.len() == rank;
                let shape = reduce_shape(&x.shape, &axes, keepdims);
                match rng.below(5) {
                    0 => b.push(Op::Sum { axes, keepdims }, vec![x.r], shape, x.dtype),
                    1 => b.push(Op::Prod { axes, keepdims }, vec![x.r], shape, x.dtype),
                    2 => b.push(Op::MaxReduce { axes, keepdims }, vec![x.r], shape, x.dtype),
                    3 => b.push(Op::MinReduce { axes, keepdims }, vec![x.r], shape, x.dtype),
                    _ => b.push(Op::Any { axes, keepdims }, vec![x.r], shape, DType::Bool),
                };
            }
            // argmax / cumsum
            7 => {
                let x = b.pick(rng);
                let axis = rng.below(x.shape.len());
                if rng.uniform() < 0.5 {
                    let keepdims = rng.uniform() < 0.5 || x.shape.len() == 1;
                    let shape = reduce_shape(&x.shape, &[axis], keepdims);
                    b.push(Op::Argmax { axis, keepdims }, vec![x.r], shape, DType::I64);
                } else {
                    b.push(Op::Cumsum { axis }, vec![x.r], x.shape.clone(), x.dtype);
                }
            }
            // data movement
            8 => {
                let x = b.pick(rng);
                let rank = x.shape.len();
                match rng.below(4) {
                    0 => {
                        let n: usize = x.shape.iter().product();
                        b.push(
                            Op::Reshape { shape: vec![n].into() },
                            vec![x.r],
                            vec![n],
                            x.dtype,
                        );
                    }
                    1 => {
                        let perm = rng.permutation(rank);
                        let shape: Vec<usize> = perm.iter().map(|&p| x.shape[p]).collect();
                        b.push(Op::Transpose { perm }, vec![x.r], shape, x.dtype);
                    }
                    2 => {
                        let axes: Vec<usize> = (0..rank).filter(|_| rng.uniform() < 0.5).collect();
                        b.push(Op::Flip { axes }, vec![x.r], x.shape.clone(), x.dtype);
                    }
                    _ => {
                        let starts: Vec<usize> =
                            x.shape.iter().map(|&d| rng.below(d)).collect();
                        let ends: Vec<usize> = x
                            .shape
                            .iter()
                            .zip(&starts)
                            .map(|(&d, &s)| s + 1 + rng.below(d - s))
                            .collect();
                        let shape: Vec<usize> =
                            ends.iter().zip(&starts).map(|(e, s)| e - s).collect();
                        b.push(Op::Slice { starts, ends }, vec![x.r], shape, x.dtype);
                    }
                }
            }
            // dtype churn
            9 => {
                let x = b.pick(rng);
                let targets = [DType::F32, DType::F64, DType::I64, DType::I32, DType::Bool];
                let dtype = targets[rng.below(targets.len())];
                b.push(Op::Astype { dtype }, vec![x.r], x.shape.clone(), dtype);
            }
            // matmul / concat(v, v) — shared operands by construction
            10 => {
                if rng.uniform() < 0.5 {
                    let (m, k, n) = (1 + rng.below(4), 1 + rng.below(4), 1 + rng.below(4));
                    let lhs = b.fresh_f32(rng, vec![m, k]);
                    let rhs = b.fresh_f32(rng, vec![k, n]);
                    b.push(Op::Matmul, vec![lhs.r, rhs.r], vec![m, n], DType::F32);
                } else {
                    let x = b.pick(rng);
                    let axis = rng.below(x.shape.len());
                    let mut shape = x.shape.clone();
                    shape[axis] *= 2;
                    b.push(Op::Concat { axis }, vec![x.r, x.r], shape, x.dtype);
                }
            }
            // select, or a dead effectful op (kept by DCE, never observed)
            _ => {
                if rng.uniform() < 0.5 && !tainted_rand {
                    // dead random op: remove it from the observable pool
                    let shape = prop::random_shape(rng, 2, 3);
                    let v = b.push(
                        Op::RandUniform {
                            shape: shape.clone().into(),
                            lo: 0.0,
                            hi: 1.0,
                            dtype: DType::F32,
                        },
                        vec![],
                        shape,
                        DType::F32,
                    );
                    let _ = v;
                    b.pool.pop(); // values drawn from the RNG are never wired up
                    tainted_rand = true;
                } else {
                    let x = b.pick(rng);
                    let y = b.companion(rng, &x.shape);
                    let shape = broadcast(&x.shape, &y.shape).unwrap();
                    let cond =
                        b.push(Op::Lt, vec![x.r, y.r], shape.clone(), DType::Bool);
                    let d = x.dtype.promote(y.dtype);
                    b.push(Op::WhereCond, vec![cond.r, x.r, y.r], shape, d);
                }
            }
        }
    }

    // request 1-3 distinct observable outputs (everything else is dead)
    let candidates: Vec<ValueRef> = b
        .pool
        .iter()
        .filter_map(|v| matches!(v.r, ValueRef::Out(_)).then_some(v.r))
        .collect();
    let mut outputs: Vec<ValueRef> = Vec::new();
    for _ in 0..(1 + rng.below(3)) {
        let pick = candidates[rng.below(candidates.len())];
        if !outputs.contains(&pick) {
            outputs.push(pick);
        }
    }
    (b.program, outputs)
}

/// Bit-level view of a materialized tensor.
fn bits(t: &Tensor) -> Vec<u64> {
    match t.to_host() {
        HostBuffer::F32(v) => v.iter().map(|x| x.to_bits() as u64).collect(),
        HostBuffer::F64(v) => v.iter().map(|x| x.to_bits()).collect(),
        HostBuffer::I32(v) => v.iter().map(|&x| x as u32 as u64).collect(),
        HostBuffer::I64(v) => v.iter().map(|&x| x as u64).collect(),
        HostBuffer::U8(v, _) => v.iter().map(|&x| x as u64).collect(),
    }
}

fn run_config(label: &str, opts: &CompileOptions, cases: usize, master_seed: u64, pinned: bool) {
    let cpu = CpuBackend::shared();
    let mut master = Rng::new(master_seed);
    for case in 0..cases {
        // a pinned (GRAPH_FUZZ_SEED) value replays itself as case 0; the
        // rest of the sweep derives from it as usual
        let case_seed = if pinned && case == 0 { master_seed } else { master.next_u64() };
        let mut rng = Rng::new(case_seed);
        let (program, outputs) = gen_program(&mut rng);
        let ctx = |stage: &str, detail: String| {
            format!(
                "graph_fuzz[{label}] case {case} (seed {case_seed:#x}): {stage}: {detail}\n\
                 ops: {:?}\noutputs: {outputs:?}\n\
                 reproduce with GRAPH_FUZZ_SEED={case_seed:#x} GRAPH_FUZZ_CASES=1",
                program.op_names()
            )
        };
        let reference = program
            .replay_on(cpu.as_ref())
            .unwrap_or_else(|e| panic!("{}", ctx("reference replay", e.to_string())));
        let compiled = compile(&program, &outputs, opts)
            .unwrap_or_else(|e| panic!("{}", ctx("compile", e.to_string())));
        // static-verifier oracle: a clean program must verify with zero
        // diagnostics, source graph and compiled form alike
        let g = Graph::from_program(&program, &outputs)
            .unwrap_or_else(|e| panic!("{}", ctx("graph lift", e.to_string())));
        let spec = verify::source_spec(&g).unwrap_or_else(|d| {
            panic!("{}", ctx("source verify", format!("{} diagnostic(s): {d:?}", d.len())))
        });
        if let Err(d) = verify::verify_program(&compiled, Some(&spec), "pipeline") {
            panic!("{}", ctx("verify oracle", format!("{} diagnostic(s): {d:?}", d.len())));
        }
        let got = compiled
            .run(cpu.as_ref())
            .unwrap_or_else(|e| panic!("{}", ctx("optimized run", e.to_string())));
        compiled
            .plan
            .check_no_aliasing()
            .unwrap_or_else(|e| panic!("{}", ctx("memory plan", e)));
        for (k, r) in outputs.iter().enumerate() {
            let want = match r {
                ValueRef::Out(i) => &reference[*i],
                ValueRef::Const(i) => &program.consts[*i],
            };
            assert!(
                got[k].dims() == want.dims() && got[k].dtype() == want.dtype(),
                "{}",
                ctx(
                    "output metadata",
                    format!(
                        "output {k}: got {:?} {}, want {:?} {} (pipeline: {})",
                        got[k].dims(),
                        got[k].dtype().name(),
                        want.dims(),
                        want.dtype().name(),
                        compiled.report.summary()
                    ),
                )
            );
            assert!(
                bits(&got[k]) == bits(want),
                "{}",
                ctx(
                    "bit mismatch",
                    format!(
                        "output {k} differs: got {:?}, want {:?} (pipeline: {})",
                        got[k].to_vec_f64(),
                        want.to_vec_f64(),
                        compiled.report.summary()
                    ),
                )
            );
        }
    }
    println!("graph_fuzz[{label}]: {cases} cases bit-identical (master seed {master_seed:#x})");
}

/// The headline run: ≥ 500 random programs through the full pipeline.
#[test]
fn differential_fuzz_full_pipeline() {
    let cases = env_usize("GRAPH_FUZZ_CASES", 500);
    let pinned = env_seed();
    run_config(
        "all",
        &CompileOptions::default(),
        cases,
        pinned.unwrap_or(0x5EED_C0DE),
        pinned.is_some(),
    );
}

/// Each pass alone (plus the pass-free lowering) against the same
/// generator, to localize a failure to a single pass.
#[test]
fn differential_fuzz_single_passes() {
    let pinned = env_seed();
    let floor = if pinned.is_some() { 1 } else { 20 };
    let cases = (env_usize("GRAPH_FUZZ_CASES", 500) / 5).max(floor);
    let seed = pinned.unwrap_or(0xDEAD_BEEF);
    run_config("none", &CompileOptions::none(), cases, seed, pinned.is_some());
    run_config("dce", &CompileOptions::only("dce"), cases, seed, pinned.is_some());
    run_config("fold", &CompileOptions::only("fold"), cases, seed, pinned.is_some());
    run_config("cse", &CompileOptions::only("cse"), cases, seed, pinned.is_some());
    run_config("fuse", &CompileOptions::only("fuse"), cases, seed, pinned.is_some());
}
