//! Compiled-train-step parity suite: a [`flashlight::coordinator`]
//! compiled step (one traced program for forward + backward + clip +
//! optimizer update, run through the graph compiler) must produce
//! **bit-identical** parameter trajectories to the eager loop — with
//! dropout enabled and gradient clipping on — single-process and at
//! world=2 through `train_data_parallel`'s bucketed all-reduce.
//!
//! RNG discipline: tracing consumes one forward's worth of draws, so each
//! run realigns the thread stream with `rng::reseed_thread` (the trainers
//! do the equivalent internally by re-seeding after compilation).

use std::sync::{Arc, Mutex, MutexGuard};

use flashlight::autograd::Variable;
use flashlight::coordinator::trainer::make_optimizer;
use flashlight::coordinator::{
    compile_step, train_classifier, train_data_parallel, train_lm, BatchSpec, TrainConfig,
};
use flashlight::data::{Dataset, TensorDataset, TransformDataset};
use flashlight::models::{mlp, BertLike};
use flashlight::nn::{categorical_cross_entropy, Dropout, Linear, Module, ReLU, Sequential};
use flashlight::optim::{clip_grad_norm, Optimizer};
use flashlight::pkg::vision::synthetic_image_classification;
use flashlight::tensor::{default_backend, Tensor};
use flashlight::util::rng;

/// Tracing swaps the process-global default backend and the parity
/// assertions depend on the thread RNG stream, so the tests in this
/// binary must not interleave tensor work: each takes this lock first.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|p| p.into_inner())
}

/// Bit patterns of every parameter (the trajectory unit).
fn param_bits(params: &[Tensor]) -> Vec<Vec<u32>> {
    params.iter().map(|p| p.to_vec().iter().map(|v| v.to_bits()).collect()).collect()
}

/// Deterministic classifier batches: `n_batches` of `[b, feat]` inputs
/// with `[b]` integer targets.
fn fixed_batches(n_batches: usize, b: usize, feat: usize, classes: usize) -> Vec<Vec<Tensor>> {
    (0..n_batches)
        .map(|k| {
            let xs: Vec<f32> = (0..b * feat)
                .map(|j| (((j * 37 + k * 101) % 19) as f32) * 0.1 - 0.9)
                .collect();
            let ys: Vec<i64> = (0..b).map(|j| ((j + k) % classes) as i64).collect();
            vec![Tensor::from_slice(&xs, [b, feat]), Tensor::from_slice(&ys, [b])]
        })
        .collect()
}

/// MLP with dropout, deterministically initialized.
fn dropout_mlp(seed: u64, feat: usize, hidden: usize, classes: usize) -> Sequential {
    rng::reseed_thread(seed);
    let mut m = Sequential::new();
    m.add(Linear::new(feat, hidden));
    m.add(ReLU);
    m.add(Dropout::new(0.25));
    m.add(Linear::new(hidden, classes));
    m
}

fn restore(model: &Sequential, p0: &[Tensor]) {
    for (p, t) in model.params().iter().zip(p0) {
        p.set_tensor(t.clone());
        p.zero_grad();
    }
}

/// The eager reference loop: exactly `train_classifier`'s arithmetic.
fn eager_trajectory(
    model: &mut Sequential,
    p0: &[Tensor],
    batches: &[Vec<Tensor>],
    cfg: &TrainConfig,
    steps: usize,
) -> Vec<Vec<Vec<u32>>> {
    restore(model, p0);
    model.set_train(true);
    rng::reseed_thread(999);
    let mut opt = make_optimizer(cfg, model.params()).unwrap();
    let mut traj = Vec::with_capacity(steps);
    for s in 0..steps {
        let batch = &batches[s % batches.len()];
        let out = model.forward(&Variable::constant(batch[0].clone()));
        let loss = categorical_cross_entropy(&out, &batch[1]);
        loss.backward();
        if cfg.grad_clip > 0.0 {
            clip_grad_norm(opt.params(), cfg.grad_clip);
        }
        opt.step();
        opt.zero_grad();
        let now: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
        traj.push(param_bits(&now));
    }
    traj
}

/// The compiled loop over the same model/batches.
fn compiled_trajectory(
    model: &mut Sequential,
    p0: &[Tensor],
    batches: &[Vec<Tensor>],
    cfg: &TrainConfig,
    steps: usize,
) -> Vec<Vec<Vec<u32>>> {
    restore(model, p0);
    model.set_train(true);
    let spec = BatchSpec::like(&batches[0]);
    let step = compile_step(&*model, cfg, &spec).unwrap();
    // tracing consumed RNG draws; realign with the eager run's stream
    rng::reseed_thread(999);
    let be = default_backend();
    let mut params: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
    let mut state = step.init_state(&params);
    let mut traj = Vec::with_capacity(steps);
    for s in 0..steps {
        let res = step.run(be.as_ref(), params, state, &batches[s % batches.len()], true).unwrap();
        params = res.params;
        state = res.state;
        assert!(res.loss.is_finite(), "step {s}: non-finite loss {}", res.loss);
        traj.push(param_bits(&params));
    }
    traj
}

fn assert_trajectories_identical(eager: &[Vec<Vec<u32>>], compiled: &[Vec<Vec<u32>>], tag: &str) {
    assert_eq!(eager.len(), compiled.len());
    for (s, (e, c)) in eager.iter().zip(compiled).enumerate() {
        for (i, (ep, cp)) in e.iter().zip(c).enumerate() {
            assert_eq!(
                ep, cp,
                "{tag}: parameter {i} diverged from the eager trajectory at step {s}"
            );
        }
    }
}

fn parity_case(optimizer: &str) {
    let mut model = dropout_mlp(11, 12, 16, 4);
    let p0: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
    let batches = fixed_batches(3, 8, 12, 4);
    let cfg = TrainConfig {
        optimizer: optimizer.into(),
        lr: 0.05,
        grad_clip: 0.05, // tight cap: clipping actually fires
        ..Default::default()
    };
    let eager = eager_trajectory(&mut model, &p0, &batches, &cfg, 20);
    let compiled = compiled_trajectory(&mut model, &p0, &batches, &cfg, 20);
    assert_trajectories_identical(&eager, &compiled, optimizer);
}

#[test]
fn sgd_momentum_with_dropout_and_clip_is_bit_identical_over_20_steps() {
    let _serial = serial();
    parity_case("sgd");
}

#[test]
fn adamw_with_dropout_and_clip_is_bit_identical_over_20_steps() {
    let _serial = serial();
    parity_case("adamw");
}

#[test]
fn compiled_step_fuses_ops_and_donation_lowers_peak() {
    let _serial = serial();
    // parameters dominate the footprint, so donating them must move the peak
    let mut model = mlp(&[32, 16, 4]);
    model.set_train(true);
    let batches = fixed_batches(1, 8, 32, 4);
    let cfg = TrainConfig { optimizer: "sgd".into(), lr: 0.1, ..Default::default() };
    let step = compile_step(&model, &cfg, &BatchSpec::like(&batches[0])).unwrap();

    // fusion is visible in the per-pass report and in the op counts
    let report = step.report();
    assert!(report.changed_by("fuse") > 0, "no fusion happened: {}", report.summary());
    let prog = step.program();
    assert!(
        prog.len() < prog.primitive_op_count(),
        "fused program should execute fewer instructions ({}) than primitive ops ({})",
        prog.len(),
        prog.primitive_op_count()
    );

    // donation: same step, same inputs, lower planned peak
    let be = default_backend();
    let params: Vec<Tensor> = model.params().iter().map(|p| p.tensor()).collect();
    let run = |donate: bool| {
        let ps: Vec<Tensor> = params.iter().map(|p| p.copy()).collect();
        let state = step.init_state(&ps);
        step.run(be.as_ref(), ps, state, &batches[0], donate).unwrap()
    };
    let kept = run(false);
    let donated = run(true);
    assert_eq!(kept.stats.donated_bytes, 0);
    assert!(donated.stats.donated_bytes > 0);
    assert!(
        donated.stats.planned_peak_bytes < kept.stats.planned_peak_bytes,
        "donation did not lower the planned peak: {} vs {}",
        donated.stats.planned_peak_bytes,
        kept.stats.planned_peak_bytes
    );
    // both runs computed the same step (dropout-free model)
    for (a, b) in kept.params.iter().zip(&donated.params) {
        assert_eq!(param_bits(&[a.clone()]), param_bits(&[b.clone()]));
    }

    // the backward/update split (the data-parallel composition, no
    // clipping) reproduces the fused full program bitwise at world=1
    let ps: Vec<Tensor> = params.iter().map(|p| p.copy()).collect();
    let state = step.init_state(&ps);
    let (grads, loss) = step.run_backward(be.as_ref(), &ps, &batches[0]).unwrap();
    let (p2, _, _) = step.run_update(be.as_ref(), ps, grads, state, true).unwrap();
    let full = run(true);
    assert_eq!(loss.to_bits(), full.loss.to_bits());
    for (a, b) in p2.iter().zip(&full.params) {
        assert_eq!(param_bits(&[a.clone()]), param_bits(&[b.clone()]));
    }
}

#[test]
fn world2_data_parallel_compiled_matches_eager_bitwise() {
    let _serial = serial();
    // deterministic replicas: random init is overwritten with fixed values
    let make_model = || -> Box<dyn Module> {
        let mut m = Sequential::new();
        m.add(Linear::new(8, 8));
        m.add(ReLU);
        m.add(Linear::new(8, 3));
        for (i, p) in m.params().iter().enumerate() {
            let n = p.numel();
            let vals: Vec<f32> =
                (0..n).map(|j| ((i * 131 + j * 17) % 23) as f32 * 0.05 - 0.5).collect();
            p.set_tensor(Tensor::from_slice(&vals, p.dims()));
        }
        Box::new(m)
    };
    let make_data = |rank: usize| -> Arc<dyn Dataset> {
        let (n, feat, classes) = (8usize, 8usize, 3usize);
        let xs: Vec<f32> = (0..n * feat)
            .map(|j| (((j * 37 + rank * 101) % 19) as f32) * 0.1 - 0.9)
            .collect();
        let ys: Vec<i64> = (0..n).map(|j| ((j + rank) % classes) as i64).collect();
        Arc::new(TensorDataset::new(vec![
            Tensor::from_slice(&xs, [n, feat]),
            Tensor::from_slice(&ys, [n]),
        ]))
    };
    let base = TrainConfig {
        optimizer: "sgd".into(),
        lr: 0.05,
        steps: 20,
        batch_size: 4,
        workers: 2,
        log_every: 1,
        ..Default::default()
    };
    let eager = train_data_parallel(make_model, make_data, &base).unwrap();
    let cfg = TrainConfig { compile_step: true, ..base };
    let compiled = train_data_parallel(make_model, make_data, &cfg).unwrap();
    assert_eq!(eager.len(), 2);
    assert_eq!(compiled.len(), 2);
    for rank in 0..2 {
        let e = &eager[rank].loss_curve;
        let c = &compiled[rank].loss_curve;
        assert_eq!(e.len(), 20, "log_every=1 must log every step");
        for ((es, el), (cs, cl)) in e.iter().zip(c) {
            assert_eq!(es, cs);
            assert_eq!(
                el.to_bits(),
                cl.to_bits(),
                "rank {rank} step {es}: compiled loss {cl} != eager loss {el}"
            );
        }
    }
}

#[test]
fn train_classifier_cfg_path_matches_eager_end_to_end() {
    let _serial = serial();
    let dataset = || -> Arc<dyn Dataset> {
        let ds = synthetic_image_classification(64, 1, 8, 4, 3);
        Arc::new(TransformDataset::new(ds, |mut s| {
            let n = s[0].numel();
            s[0] = s[0].reshape(&[1, n as isize]);
            s
        }))
    };
    let fresh_model = || {
        rng::reseed_thread(5);
        let mut m = Sequential::new();
        m.add(Linear::new(64, 32));
        m.add(ReLU);
        m.add(Dropout::new(0.2));
        m.add(Linear::new(32, 4));
        m
    };
    let base = TrainConfig {
        optimizer: "adamw".into(),
        lr: 3e-3,
        steps: 12,
        batch_size: 16,
        grad_clip: 0.1,
        log_every: 3,
        eval_batches: 2,
        seed: 42,
        ..Default::default()
    };
    let mut m1 = fresh_model();
    let eager = train_classifier(&mut m1, dataset(), &base, |_, _| {}).unwrap();
    let mut m2 = fresh_model();
    let cfg = TrainConfig { compile_step: true, ..base };
    let compiled = train_classifier(&mut m2, dataset(), &cfg, |_, _| {}).unwrap();

    assert_eq!(eager.loss_curve.len(), compiled.loss_curve.len());
    for ((es, el), (cs, cl)) in eager.loss_curve.iter().zip(&compiled.loss_curve) {
        assert_eq!(es, cs);
        assert_eq!(el.to_bits(), cl.to_bits(), "loss curves diverged at step {es}");
    }
    let pe: Vec<Tensor> = m1.params().iter().map(|p| p.tensor()).collect();
    let pc: Vec<Tensor> = m2.params().iter().map(|p| p.tensor()).collect();
    assert_eq!(param_bits(&pe), param_bits(&pc), "final parameters diverged");
    assert_eq!(eager.eval_error.unwrap().to_bits(), compiled.eval_error.unwrap().to_bits());
}

#[test]
fn train_lm_cfg_path_matches_eager_end_to_end() {
    let _serial = serial();
    let dataset = || -> Arc<dyn Dataset> {
        let (n, l1) = (24usize, 7usize);
        let ids: Vec<i64> = (0..n * l1).map(|j| ((j * 13 + 5) % 16) as i64).collect();
        Arc::new(TensorDataset::new(vec![
            Tensor::from_slice(&ids, [n, l1]).astype(flashlight::tensor::DType::I64),
        ]))
    };
    let fresh_model = || {
        rng::reseed_thread(3);
        BertLike::new(16, 8, 2, 1, 12)
    };
    let base = TrainConfig {
        optimizer: "adam".into(),
        lr: 1e-3,
        steps: 6,
        batch_size: 4,
        grad_clip: 0.5,
        log_every: 2,
        seed: 17,
        ..Default::default()
    };
    let m1 = fresh_model();
    let eager = train_lm(&m1, dataset(), &base, |_, _| {}).unwrap();
    let m2 = fresh_model();
    let cfg = TrainConfig { compile_step: true, ..base };
    let compiled = train_lm(&m2, dataset(), &cfg, |_, _| {}).unwrap();

    assert_eq!(eager.loss_curve.len(), compiled.loss_curve.len());
    for ((es, el), (cs, cl)) in eager.loss_curve.iter().zip(&compiled.loss_curve) {
        assert_eq!(es, cs);
        assert_eq!(el.to_bits(), cl.to_bits(), "LM loss curves diverged at step {es}");
    }
    let pe: Vec<Tensor> = m1.params().iter().map(|p| p.tensor()).collect();
    let pc: Vec<Tensor> = m2.params().iter().map(|p| p.tensor()).collect();
    assert_eq!(param_bits(&pe), param_bits(&pc), "final LM parameters diverged");
}

#[test]
fn unknown_optimizer_is_an_error_not_a_silent_adam() {
    let _serial = serial();
    let cfg = TrainConfig { optimizer: "lion".into(), ..Default::default() };
    assert!(make_optimizer(&cfg, Vec::new()).is_err());
    let model = mlp(&[4, 2]);
    let batches = fixed_batches(1, 2, 4, 2);
    assert!(compile_step(&model, &cfg, &BatchSpec::like(&batches[0])).is_err());
}
