//! Serving-engine correctness suite.
//!
//! The two acceptance contracts, enforced bitwise (`f32::to_bits`, no
//! tolerances):
//!
//! 1. KV-cached incremental decode is **bit-identical** to full-context
//!    recompute decode for ≥ 64 generated tokens.
//! 2. A request served through the dynamic batcher is **bit-identical**
//!    to the same request served at batch size 1.
//!
//! Plus behavioral coverage of the batching policy (deadline flush,
//! coalescing, padding, graceful shutdown) and the session's shape
//! bucketing.

use std::sync::Arc;
use std::time::Duration;

use flashlight::models::BertLike;
use flashlight::serve::{
    generate, Engine, EngineConfig, GenerateOptions, InferenceSession, Sampling,
};
use flashlight::tensor::{DType, Tensor};
use flashlight::util::rng::Rng;

/// A small causal LM with deterministic (per-test) random weights.
fn small_lm(vocab: usize, max_len: usize) -> BertLike {
    BertLike::new(vocab, 32, 4, 2, max_len)
}

fn random_ids(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i64> {
    (0..n).map(|_| rng.below(vocab) as i64).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

// ---- contract 1: KV-cached decode ≡ full recompute ------------------------

#[test]
fn kv_cached_logits_bit_identical_to_recompute_for_64_tokens() {
    let model = small_lm(48, 80);
    let mut rng = Rng::new(11);
    let mut tokens = random_ids(&mut rng, 8, 48);

    let mut caches = model.empty_cache();
    let prefill = Tensor::from_slice(&tokens, [1, tokens.len()]);
    let mut cached_last: Vec<f32> = {
        let logits = model.logits_cached(&prefill, &mut caches).tensor();
        logits.narrow(1, tokens.len() - 1, 1).to_vec()
    };

    for step in 0..64 {
        // reference: recompute the whole context eagerly, take the last row
        let ctx = Tensor::from_slice(&tokens, [1, tokens.len()]);
        let full = model.logits(&ctx).tensor();
        let full_last: Vec<f32> = full.narrow(1, tokens.len() - 1, 1).to_vec();
        assert_eq!(
            bits(&cached_last),
            bits(&full_last),
            "cached decode diverged from recompute at generated token {step}"
        );
        // greedy next token from the (identical) logits
        let mut best = 0usize;
        for (i, &v) in cached_last.iter().enumerate() {
            if v > cached_last[best] {
                best = i;
            }
        }
        tokens.push(best as i64);
        cached_last = model
            .logits_cached(&Tensor::from_slice(&[best as i64], [1, 1]), &mut caches)
            .tensor()
            .to_vec();
    }
    assert_eq!(caches[0].len(), 8 + 64, "cache must hold every processed position");
}

#[test]
fn generate_cached_and_uncached_agree_greedy_and_topk() {
    let model = small_lm(64, 96);
    let mut rng = Rng::new(29);
    let prompt = random_ids(&mut rng, 6, 64);

    for sampling in [Sampling::Greedy, Sampling::TopK { k: 8, temperature: 0.9 }] {
        let opts = |use_cache| GenerateOptions {
            max_new_tokens: 64,
            sampling: sampling.clone(),
            seed: 1234,
            use_cache,
        };
        let cached = generate(&model, &prompt, &opts(true)).unwrap();
        let recompute = generate(&model, &prompt, &opts(false)).unwrap();
        assert_eq!(
            cached.tokens, recompute.tokens,
            "cached vs recompute token streams diverged under {sampling:?}"
        );
        assert_eq!(cached.generated, 64);
        assert_eq!(cached.tokens.len(), prompt.len() + 64);
        assert!(cached.tokens.iter().all(|&t| (t as usize) < 64));
    }
}

#[test]
fn generate_is_reproducible_per_seed_and_validates_inputs() {
    let model = small_lm(32, 40);
    let prompt = [1i64, 5, 9];
    let topk = |seed| GenerateOptions {
        max_new_tokens: 12,
        sampling: Sampling::TopK { k: 5, temperature: 1.1 },
        seed,
        use_cache: true,
    };
    let a = generate(&model, &prompt, &topk(7)).unwrap();
    let b = generate(&model, &prompt, &topk(7)).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must replay the same stream");

    // empty prompts, context overflow, and bad sampling knobs are rejected
    assert!(generate(&model, &[], &GenerateOptions::default()).is_err());
    let too_long = GenerateOptions { max_new_tokens: 40, ..Default::default() };
    assert!(generate(&model, &prompt, &too_long).is_err());
    let bad_k = GenerateOptions {
        sampling: Sampling::TopK { k: 0, temperature: 1.0 },
        ..Default::default()
    };
    assert!(generate(&model, &prompt, &bad_k).is_err());
    let bad_t = GenerateOptions {
        sampling: Sampling::TopK { k: 3, temperature: 0.0 },
        ..Default::default()
    };
    assert!(generate(&model, &prompt, &bad_t).is_err());
}

// ---- contract 2: batched ≡ solo through the compiled session --------------

#[test]
fn batched_requests_bit_identical_to_solo_service() {
    let model = Arc::new(small_lm(40, 24));
    let seq = 10usize;
    let traced = Arc::clone(&model);
    let session = InferenceSession::compile(&[seq], DType::I64, &[1, 4], move |ids| {
        traced.logits(ids).tensor()
    })
    .unwrap();

    let mut rng = Rng::new(3);
    let requests: Vec<Tensor> = (0..3)
        .map(|_| Tensor::from_slice(&random_ids(&mut rng, seq, 40), [seq]))
        .collect();

    // solo references through the batch-1 bucket
    let solo: Vec<Vec<f32>> = requests
        .iter()
        .map(|r| session.run_one(r.copy()).unwrap().to_vec())
        .collect();

    // the same three requests as one padded batch (3 rows -> bucket 4)
    let refs: Vec<&Tensor> = requests.iter().collect();
    let out = session.run_batch(Tensor::stack(&refs, 0)).unwrap();
    assert_eq!(out.dims(), &[3, seq, 40], "padding rows must be sliced back off");
    for (i, solo_row) in solo.iter().enumerate() {
        let batched_row: Vec<f32> = out.narrow(0, i, 1).to_vec();
        assert_eq!(
            bits(&batched_row),
            bits(solo_row),
            "request {i} served batched diverged from solo service"
        );
    }
}

#[test]
fn engine_serves_batched_requests_bit_identically_and_coalesces() {
    let model = Arc::new(small_lm(40, 24));
    let seq = 10usize;
    let cfg = EngineConfig {
        max_batch_size: 8,
        max_wait: Duration::from_millis(300),
        workers: 1,
    };
    let engine = Engine::start_lm(Arc::clone(&model), seq, &[1, 8], &cfg).unwrap();

    let mut rng = Rng::new(17);
    let inputs: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_slice(&random_ids(&mut rng, seq, 40), [seq]))
        .collect();

    // enqueue everything before waiting so the single worker can coalesce
    let handles: Vec<_> = inputs.iter().map(|t| engine.submit(t.copy())).collect();
    let responses: Vec<Tensor> = handles.into_iter().map(|h| h.wait().unwrap()).collect();

    // references served one-by-one through a fresh batch-1 session
    let traced = Arc::clone(&model);
    let solo_session = InferenceSession::compile(&[seq], DType::I64, &[1], move |ids| {
        traced.logits(ids).tensor()
    })
    .unwrap();
    for (i, (input, resp)) in inputs.iter().zip(&responses).enumerate() {
        assert_eq!(resp.dims(), &[seq, 40]);
        let solo = solo_session.run_one(input.copy()).unwrap();
        assert_eq!(
            bits(&resp.to_vec()),
            bits(&solo.to_vec()),
            "engine response {i} diverged from solo service"
        );
    }

    let stats = engine.stats();
    assert_eq!(stats.batcher.requests, 8);
    assert!(
        stats.batcher.batches < stats.batcher.requests,
        "a 300ms window with 8 queued requests must coalesce (got {} batches)",
        stats.batcher.batches
    );
    assert!(stats.batcher.mean_batch_fill > 1.0);
    assert!(stats.batcher.latency_p50_us > 0.0);
    assert!(stats.batcher.latency_p99_us >= stats.batcher.latency_p50_us);
    engine.shutdown();
}

#[test]
fn single_request_flushes_at_the_deadline() {
    let model = Arc::new(small_lm(24, 16));
    let cfg = EngineConfig {
        max_batch_size: 8,
        max_wait: Duration::from_millis(10),
        workers: 2,
    };
    let engine = Engine::start_lm(model, 6, &[1, 8], &cfg).unwrap();
    // nobody else is queuing: the lone request must still be answered
    let ids = Tensor::from_slice(&[1i64, 2, 3, 4, 5, 6], [6]);
    let out = engine.infer(ids).unwrap();
    assert_eq!(out.dims(), &[6, 24]);
    let stats = engine.stats();
    assert_eq!(stats.batcher.requests, 1);
    assert_eq!(stats.batcher.batches, 1);

    // malformed requests are rejected at submit time — they must neither
    // panic a worker nor poison a cohort batch
    let wrong_shape = Tensor::from_slice(&[1i64, 2, 3], [3]);
    assert!(engine.infer(wrong_shape).is_err());
    let wrong_dtype = Tensor::rand([6], 0.0, 1.0);
    assert!(engine.infer(wrong_dtype).is_err());
    // and a well-formed request afterwards is still served
    let ok = engine.infer(Tensor::from_slice(&[0i64; 6], [6])).unwrap();
    assert_eq!(ok.dims(), &[6, 24]);
}

#[test]
fn shutdown_serves_already_queued_requests() {
    let model = Arc::new(small_lm(24, 16));
    let cfg = EngineConfig {
        max_batch_size: 4,
        max_wait: Duration::from_millis(1),
        workers: 1,
    };
    let engine = Engine::start_lm(model, 6, &[1, 4], &cfg).unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| engine.submit(Tensor::from_slice(&[i as i64; 6], [6])))
        .collect();
    // graceful: shutdown joins the workers only after the queue drains
    engine.shutdown();
    for h in handles {
        let out = h.wait().expect("queued request must be served before shutdown");
        assert_eq!(out.dims(), &[6, 24]);
    }
}

// ---- session-level behavior ----------------------------------------------

#[test]
fn session_buckets_validate_and_route() {
    let session = InferenceSession::compile(&[3], DType::F32, &[2, 4, 1], |x| {
        x.mul(x).add_scalar(1.0)
    })
    .unwrap();
    assert_eq!(session.bucket_sizes(), vec![1, 2, 4]);
    assert_eq!(session.max_batch(), 4);
    assert_eq!(session.bucket_for(3), Some(4));
    assert_eq!(session.bucket_for(5), None);
    assert_eq!(session.output_dims(), &[3]);

    // routing pads 3 rows into the 4-bucket and slices back
    let batch = Tensor::rand([3, 3], -1.0, 1.0);
    let out = session.run_batch(batch.copy()).unwrap();
    assert_eq!(out.dims(), &[3, 3]);
    let direct = batch.mul(&batch).add_scalar(1.0);
    assert_eq!(bits(&out.to_vec()), bits(&direct.to_vec()));

    // a single example loses its batch axis
    let one = session.run_one(Tensor::rand([3], -1.0, 1.0)).unwrap();
    assert_eq!(one.dims(), &[3]);

    // oversized batches, wrong dtypes, and wrong shapes are rejected
    assert!(session.run_batch(Tensor::rand([5, 3], -1.0, 1.0)).is_err());
    assert!(session.run_batch(Tensor::rand([2, 4], -1.0, 1.0)).is_err());
    assert!(session
        .run_batch(Tensor::rand([2, 3], 0.0, 1.0).astype(DType::I64))
        .is_err());
    // and so are degenerate bucket lists
    assert!(InferenceSession::compile(&[3], DType::F32, &[], |x| x.copy()).is_err());
    assert!(InferenceSession::compile(&[3], DType::F32, &[0], |x| x.copy()).is_err());
    // a non-batch-major forward is caught at compile time
    assert!(
        InferenceSession::compile(&[3], DType::F32, &[2], |x| x.sum(&[], false)).is_err(),
        "reducing away the batch axis must be rejected"
    );
}

#[test]
fn steady_state_serving_does_not_retrace() {
    // trace_and_compile runs the forward closure exactly once per bucket;
    // count invocations to prove steady-state serving never re-traces
    use std::sync::atomic::{AtomicUsize, Ordering};
    let traces = Arc::new(AtomicUsize::new(0));
    let counter = Arc::clone(&traces);
    let session = InferenceSession::compile(&[2], DType::F32, &[1, 2], move |x| {
        counter.fetch_add(1, Ordering::SeqCst);
        x.tanh()
    })
    .unwrap();
    assert_eq!(traces.load(Ordering::SeqCst), 2, "one trace per bucket");
    for i in 0..50 {
        let x = Tensor::from_slice(&[i as f32, -0.5 * i as f32], [2]);
        let y = session.run_one(x.copy()).unwrap();
        assert_eq!(bits(&y.to_vec()), bits(&x.tanh().to_vec()));
    }
    assert_eq!(traces.load(Ordering::SeqCst), 2, "serving must not re-trace");
}
