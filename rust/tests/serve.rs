//! Serving-engine correctness suite.
//!
//! The two acceptance contracts, enforced bitwise (`f32::to_bits`, no
//! tolerances):
//!
//! 1. KV-cached incremental decode is **bit-identical** to full-context
//!    recompute decode for ≥ 64 generated tokens.
//! 2. A request served through the dynamic batcher is **bit-identical**
//!    to the same request served at batch size 1.
//!
//! 3. A request served through the continuous (iteration-level) batcher
//!    is **bit-identical** — token stream and per-step logits — to a solo
//!    `generate()` call, whatever else shares the decode batch.
//!
//! Plus behavioral coverage of the batching policy (deadline flush,
//! coalescing, padding, graceful shutdown), the continuous scheduler's
//! admission/backpressure policy, submit/shutdown race-freedom, and the
//! session's shape bucketing. Randomized arrival/retirement schedules are
//! covered separately by `rust/tests/serve_continuous_fuzz.rs`.

use std::sync::Arc;
use std::time::Duration;

use flashlight::autograd::no_grad;
use flashlight::memory::KvPagePool;
use flashlight::models::BertLike;
use flashlight::nn::PagedKvCache;
use flashlight::serve::{
    generate, CompiledDecodeStep, ContinuousBatcher, ContinuousConfig, Engine, EngineConfig,
    GenerateOptions, InferenceSession, Sampling,
};
use flashlight::tensor::{DType, Tensor};
use flashlight::util::error::Error;
use flashlight::util::rng::Rng;

/// A small causal LM with deterministic (per-test) random weights.
fn small_lm(vocab: usize, max_len: usize) -> BertLike {
    BertLike::new(vocab, 32, 4, 2, max_len)
}

fn random_ids(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i64> {
    (0..n).map(|_| rng.below(vocab) as i64).collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Shorthand for struct-update spreads on [`ContinuousConfig`] literals.
fn def() -> ContinuousConfig {
    ContinuousConfig::default()
}

fn argmax(v: &[f32]) -> i64 {
    let mut best = 0usize;
    for (i, &x) in v.iter().enumerate() {
        if x > v[best] {
            best = i;
        }
    }
    best as i64
}

// ---- contract 1: KV-cached decode ≡ full recompute ------------------------

#[test]
fn kv_cached_logits_bit_identical_to_recompute_for_64_tokens() {
    let model = small_lm(48, 80);
    let mut rng = Rng::new(11);
    let mut tokens = random_ids(&mut rng, 8, 48);

    let mut caches = model.empty_cache();
    let prefill = Tensor::from_slice(&tokens, [1, tokens.len()]);
    let mut cached_last: Vec<f32> = {
        let logits = model.logits_cached(&prefill, &mut caches).tensor();
        logits.narrow(1, tokens.len() - 1, 1).to_vec()
    };

    for step in 0..64 {
        // reference: recompute the whole context eagerly, take the last row
        let ctx = Tensor::from_slice(&tokens, [1, tokens.len()]);
        let full = model.logits(&ctx).tensor();
        let full_last: Vec<f32> = full.narrow(1, tokens.len() - 1, 1).to_vec();
        assert_eq!(
            bits(&cached_last),
            bits(&full_last),
            "cached decode diverged from recompute at generated token {step}"
        );
        // greedy next token from the (identical) logits
        let mut best = 0usize;
        for (i, &v) in cached_last.iter().enumerate() {
            if v > cached_last[best] {
                best = i;
            }
        }
        tokens.push(best as i64);
        cached_last = model
            .logits_cached(&Tensor::from_slice(&[best as i64], [1, 1]), &mut caches)
            .tensor()
            .to_vec();
    }
    assert_eq!(caches[0].len(), 8 + 64, "cache must hold every processed position");
}

#[test]
fn generate_cached_and_uncached_agree_greedy_and_topk() {
    let model = small_lm(64, 96);
    let mut rng = Rng::new(29);
    let prompt = random_ids(&mut rng, 6, 64);

    for sampling in [Sampling::Greedy, Sampling::TopK { k: 8, temperature: 0.9 }] {
        let opts = |use_cache| GenerateOptions {
            max_new_tokens: 64,
            sampling: sampling.clone(),
            seed: 1234,
            use_cache,
            record_logits: false,
        };
        let cached = generate(&model, &prompt, &opts(true)).unwrap();
        let recompute = generate(&model, &prompt, &opts(false)).unwrap();
        assert_eq!(
            cached.tokens, recompute.tokens,
            "cached vs recompute token streams diverged under {sampling:?}"
        );
        assert_eq!(cached.generated, 64);
        assert_eq!(cached.tokens.len(), prompt.len() + 64);
        assert!(cached.tokens.iter().all(|&t| (t as usize) < 64));
    }
}

#[test]
fn generate_is_reproducible_per_seed_and_validates_inputs() {
    let model = small_lm(32, 40);
    let prompt = [1i64, 5, 9];
    let topk = |seed| GenerateOptions {
        max_new_tokens: 12,
        sampling: Sampling::TopK { k: 5, temperature: 1.1 },
        seed,
        use_cache: true,
        record_logits: false,
    };
    let a = generate(&model, &prompt, &topk(7)).unwrap();
    let b = generate(&model, &prompt, &topk(7)).unwrap();
    assert_eq!(a.tokens, b.tokens, "same seed must replay the same stream");

    // empty prompts, context overflow, and bad sampling knobs are rejected
    assert!(generate(&model, &[], &GenerateOptions::default()).is_err());
    let too_long = GenerateOptions { max_new_tokens: 40, ..Default::default() };
    assert!(generate(&model, &prompt, &too_long).is_err());
    let bad_k = GenerateOptions {
        sampling: Sampling::TopK { k: 0, temperature: 1.0 },
        ..Default::default()
    };
    assert!(generate(&model, &prompt, &bad_k).is_err());
    let bad_t = GenerateOptions {
        sampling: Sampling::TopK { k: 3, temperature: 0.0 },
        ..Default::default()
    };
    assert!(generate(&model, &prompt, &bad_t).is_err());
}

// ---- contract 2: batched ≡ solo through the compiled session --------------

#[test]
fn batched_requests_bit_identical_to_solo_service() {
    let model = Arc::new(small_lm(40, 24));
    let seq = 10usize;
    let traced = Arc::clone(&model);
    let session = InferenceSession::compile(&[seq], DType::I64, &[1, 4], move |ids| {
        traced.logits(ids).tensor()
    })
    .unwrap();

    let mut rng = Rng::new(3);
    let requests: Vec<Tensor> = (0..3)
        .map(|_| Tensor::from_slice(&random_ids(&mut rng, seq, 40), [seq]))
        .collect();

    // solo references through the batch-1 bucket
    let solo: Vec<Vec<f32>> = requests
        .iter()
        .map(|r| session.run_one(r.copy()).unwrap().to_vec())
        .collect();

    // the same three requests as one padded batch (3 rows -> bucket 4)
    let refs: Vec<&Tensor> = requests.iter().collect();
    let out = session.run_batch(Tensor::stack(&refs, 0)).unwrap();
    assert_eq!(out.dims(), &[3, seq, 40], "padding rows must be sliced back off");
    for (i, solo_row) in solo.iter().enumerate() {
        let batched_row: Vec<f32> = out.narrow(0, i, 1).to_vec();
        assert_eq!(
            bits(&batched_row),
            bits(solo_row),
            "request {i} served batched diverged from solo service"
        );
    }
}

#[test]
fn engine_serves_batched_requests_bit_identically_and_coalesces() {
    let model = Arc::new(small_lm(40, 24));
    let seq = 10usize;
    let cfg = EngineConfig {
        max_batch_size: 8,
        max_wait: Duration::from_millis(300),
        workers: 1,
        ..Default::default()
    };
    let engine = Engine::start_lm(Arc::clone(&model), seq, &[1, 8], &cfg).unwrap();

    let mut rng = Rng::new(17);
    let inputs: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_slice(&random_ids(&mut rng, seq, 40), [seq]))
        .collect();

    // enqueue everything before waiting so the single worker can coalesce
    let handles: Vec<_> = inputs.iter().map(|t| engine.submit(t.copy())).collect();
    let responses: Vec<Tensor> = handles.into_iter().map(|h| h.wait().unwrap()).collect();

    // references served one-by-one through a fresh batch-1 session
    let traced = Arc::clone(&model);
    let solo_session = InferenceSession::compile(&[seq], DType::I64, &[1], move |ids| {
        traced.logits(ids).tensor()
    })
    .unwrap();
    for (i, (input, resp)) in inputs.iter().zip(&responses).enumerate() {
        assert_eq!(resp.dims(), &[seq, 40]);
        let solo = solo_session.run_one(input.copy()).unwrap();
        assert_eq!(
            bits(&resp.to_vec()),
            bits(&solo.to_vec()),
            "engine response {i} diverged from solo service"
        );
    }

    let stats = engine.stats();
    assert_eq!(stats.batcher.requests, 8);
    assert!(
        stats.batcher.batches < stats.batcher.requests,
        "a 300ms window with 8 queued requests must coalesce (got {} batches)",
        stats.batcher.batches
    );
    assert!(stats.batcher.mean_batch_fill > 1.0);
    assert!(stats.batcher.latency_p50_us > 0.0);
    assert!(stats.batcher.latency_p99_us >= stats.batcher.latency_p50_us);
    engine.shutdown();
}

#[test]
fn single_request_flushes_at_the_deadline() {
    let model = Arc::new(small_lm(24, 16));
    let cfg = EngineConfig {
        max_batch_size: 8,
        max_wait: Duration::from_millis(10),
        workers: 2,
        ..Default::default()
    };
    let engine = Engine::start_lm(model, 6, &[1, 8], &cfg).unwrap();
    // nobody else is queuing: the lone request must still be answered
    let ids = Tensor::from_slice(&[1i64, 2, 3, 4, 5, 6], [6]);
    let out = engine.infer(ids).unwrap();
    assert_eq!(out.dims(), &[6, 24]);
    let stats = engine.stats();
    assert_eq!(stats.batcher.requests, 1);
    assert_eq!(stats.batcher.batches, 1);

    // malformed requests are rejected at submit time — they must neither
    // panic a worker nor poison a cohort batch
    let wrong_shape = Tensor::from_slice(&[1i64, 2, 3], [3]);
    assert!(engine.infer(wrong_shape).is_err());
    let wrong_dtype = Tensor::rand([6], 0.0, 1.0);
    assert!(engine.infer(wrong_dtype).is_err());
    // and a well-formed request afterwards is still served
    let ok = engine.infer(Tensor::from_slice(&[0i64; 6], [6])).unwrap();
    assert_eq!(ok.dims(), &[6, 24]);
}

#[test]
fn shutdown_serves_already_queued_requests() {
    let model = Arc::new(small_lm(24, 16));
    let cfg = EngineConfig {
        max_batch_size: 4,
        max_wait: Duration::from_millis(1),
        workers: 1,
        ..Default::default()
    };
    let engine = Engine::start_lm(model, 6, &[1, 4], &cfg).unwrap();
    let handles: Vec<_> = (0..6)
        .map(|i| engine.submit(Tensor::from_slice(&[i as i64; 6], [6])))
        .collect();
    // graceful: shutdown joins the workers only after the queue drains
    engine.shutdown();
    for h in handles {
        let out = h.wait().expect("queued request must be served before shutdown");
        assert_eq!(out.dims(), &[6, 24]);
    }
}

// ---- contract 3: continuous batching ≡ solo decode ------------------------

fn gen_opts(seed: u64, max_new: usize, sampling: Sampling) -> GenerateOptions {
    GenerateOptions {
        max_new_tokens: max_new,
        sampling,
        seed,
        use_cache: true,
        record_logits: true,
    }
}

fn assert_report_matches_solo(
    model: &BertLike,
    prompt: &[i64],
    opts: &GenerateOptions,
    served: &flashlight::serve::GenerateReport,
    who: &str,
) {
    let solo = generate(model, prompt, opts).unwrap();
    assert_eq!(served.tokens, solo.tokens, "{who}: token stream diverged from solo decode");
    assert_eq!(served.generated, solo.generated);
    assert_eq!(
        served.step_logits.len(),
        solo.step_logits.len(),
        "{who}: step-logit count diverged"
    );
    for (step, (a, b)) in served.step_logits.iter().zip(&solo.step_logits).enumerate() {
        assert_eq!(bits(a), bits(b), "{who}: step {step} logits diverged from solo decode");
    }
}

#[test]
fn continuous_batched_generation_bit_identical_to_solo() {
    let model = Arc::new(small_lm(48, 64));
    let cfg = ContinuousConfig { max_active: 4, page_tokens: 4, pool_pages: None, ..def() };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();

    let mut rng = Rng::new(41);
    let requests: Vec<(Vec<i64>, GenerateOptions)> = (0..6)
        .map(|i| {
            let n = 2 + rng.below(6);
            let prompt = random_ids(&mut rng, n, 48);
            let sampling = if i % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 6, temperature: 0.8 }
            };
            (prompt, gen_opts(100 + i as u64, 4 + i, sampling))
        })
        .collect();

    // enqueue everything up front so requests of different lengths share
    // (and progressively leave) the iteration batch
    let handles: Vec<_> = requests.iter().map(|(p, o)| batcher.submit(p, o)).collect();
    for ((prompt, opts), handle) in requests.iter().zip(handles) {
        let served = handle.wait().unwrap();
        assert_report_matches_solo(&model, prompt, opts, &served, "continuous");
    }

    let stats = batcher.stats();
    assert_eq!(stats.submitted, 6);
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.prefills, 6);
    assert_eq!(stats.prefill_chunks, 6, "no chunking: one prefill pass per admission");
    assert_eq!(stats.chunked_admissions, 0);
    assert_eq!(stats.generated_tokens, (0..6).map(|i| 4 + i as u64).sum::<u64>());
    assert!(stats.iterations > 0);
    // the default (auto) buckets cover every feasible batch size, so the
    // whole run decodes through the pre-compiled programs
    assert_eq!(stats.compile_misses, 0, "auto buckets must cover every batch size");
    assert_eq!(stats.compiled_iterations, stats.iterations);
    assert!(stats.mean_iteration_batch >= 1.0);
    assert!(stats.occupancy_peak >= 1.0);
    assert_eq!(stats.pool.leased_pages, 0, "retired requests must return every KV page");
    assert_eq!(stats.pool.total_leases, stats.pool.total_releases);
    batcher.shutdown();
}

#[test]
fn backpressured_admission_stalls_then_serves_every_request_bitwise() {
    let model = Arc::new(small_lm(32, 32));
    // 6-token prompt + 10 new = 16 positions = 4 pages of 4 tokens; the
    // pool holds exactly one request's reservation, so admission of the
    // queue's head must stall until the running request retires
    let cfg = ContinuousConfig { max_active: 4, page_tokens: 4, pool_pages: Some(4), ..def() };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();

    let mut rng = Rng::new(7);
    let prompts: Vec<Vec<i64>> = (0..3).map(|_| random_ids(&mut rng, 6, 32)).collect();
    let opts = gen_opts(11, 10, Sampling::TopK { k: 4, temperature: 1.0 });
    let handles: Vec<_> = prompts.iter().map(|p| batcher.submit(p, &opts)).collect();
    for (prompt, handle) in prompts.iter().zip(handles) {
        let served = handle.wait().unwrap();
        assert_report_matches_solo(&model, prompt, &opts, &served, "backpressured");
    }

    let stats = batcher.stats();
    assert_eq!(stats.completed, 3);
    assert!(
        stats.backpressure_stalls > 0,
        "a one-request pool with three queued requests must stall admissions"
    );
    assert_eq!(stats.pool.leased_pages, 0);
    assert_eq!(stats.pool.total_leases, stats.pool.total_releases);
    batcher.shutdown();
}

#[test]
fn continuous_submit_validates_and_answers_zero_token_requests() {
    let model = Arc::new(small_lm(24, 20));
    let cfg = ContinuousConfig { max_active: 2, page_tokens: 4, pool_pages: Some(3), ..def() };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();

    // empty prompts, context overflow, and bad sampling knobs fail fast
    assert!(batcher.generate(&[], &GenerateOptions::default()).is_err());
    let too_long = GenerateOptions { max_new_tokens: 20, ..Default::default() };
    assert!(batcher.generate(&[1, 2, 3], &too_long).is_err());
    let bad_k = GenerateOptions {
        max_new_tokens: 4,
        sampling: Sampling::TopK { k: 0, temperature: 1.0 },
        ..Default::default()
    };
    assert!(batcher.generate(&[1, 2], &bad_k).is_err());

    // KV demand beyond the whole pool is a typed, permanent rejection
    // (4 prompt + 9 new = 13 positions = 4 pages > the pool's 3)
    let hungry = GenerateOptions { max_new_tokens: 9, ..Default::default() };
    let err = batcher.generate(&[1, 2, 3, 4], &hungry).unwrap_err();
    assert!(matches!(err, Error::Memory(_)), "want Error::Memory, got {err:?}");

    // zero-token requests answer immediately with the prompt unchanged
    let none = GenerateOptions { max_new_tokens: 0, ..Default::default() };
    let r = batcher.generate(&[5, 6, 7], &none).unwrap();
    assert_eq!(r.tokens, vec![5, 6, 7]);
    assert_eq!(r.generated, 0);
    assert_eq!(r.prefill_chunks, 0, "a zero-token request never runs a prefill");

    // and a servable request afterwards still goes through
    let ok = gen_opts(0, 4, Sampling::Greedy);
    let served = batcher.generate(&[3, 1, 2], &ok).unwrap();
    assert_report_matches_solo(&model, &[3, 1, 2], &ok, &served, "post-rejection");
    batcher.shutdown();
}

#[test]
fn engine_generate_matches_solo_and_reports_decode_stats() {
    let model = Arc::new(small_lm(32, 48));
    let cfg = EngineConfig {
        max_batch_size: 2,
        max_wait: Duration::from_millis(5),
        workers: 1,
        decode: ContinuousConfig { max_active: 2, page_tokens: 4, pool_pages: None, ..def() },
    };
    let engine = Engine::start_lm(Arc::clone(&model), 8, &[1], &cfg).unwrap();
    let opts = gen_opts(3, 6, Sampling::Greedy);
    let prompt = [4i64, 9, 2, 7];
    let handles: Vec<_> = (0..3).map(|_| engine.submit_generate(&prompt, &opts).unwrap()).collect();
    for h in handles {
        let served = h.wait().unwrap();
        assert_report_matches_solo(&model, &prompt, &opts, &served, "engine");
    }
    let stats = engine.stats();
    let decode = stats.decode.expect("LM engines always have a decoder");
    assert_eq!(decode.completed, 3);
    assert_eq!(stats.generated_tokens, 18);
    assert!(stats.decode_tokens_per_sec > 0.0);
    assert!(decode.latency_p99_us >= decode.latency_p50_us);
    assert_eq!(decode.pool.leased_pages, 0);
    engine.shutdown();
    // generation requests after shutdown fail cleanly instead of hanging
    assert!(engine.generate(&prompt, &opts).is_err());
}

// ---- submit/shutdown races ------------------------------------------------

#[test]
fn submit_after_shutdown_fails_cleanly_and_shutdown_is_idempotent() {
    use flashlight::serve::{Batcher, BatcherConfig};
    let session = InferenceSession::compile(&[2], DType::F32, &[1], |x| x.tanh()).unwrap();
    let batcher = Batcher::start(Arc::new(session), BatcherConfig::default());
    let served = batcher.submit(Tensor::from_slice(&[0.25f32, -0.5], [2])).wait().unwrap();
    assert_eq!(served.dims(), &[2]);
    batcher.shutdown();
    batcher.shutdown(); // idempotent
    // late submission: the handle resolves with an error, never hangs
    let late = batcher.submit(Tensor::from_slice(&[1.0f32, 2.0], [2]));
    assert!(late.wait().is_err(), "a post-shutdown submit must fail cleanly");

    // same contract on the continuous scheduler
    let model = Arc::new(small_lm(24, 16));
    let decoder = ContinuousBatcher::start(model, &ContinuousConfig::default()).unwrap();
    decoder.shutdown();
    decoder.shutdown();
    let opts = GenerateOptions { max_new_tokens: 2, ..Default::default() };
    assert!(decoder.generate(&[1, 2], &opts).is_err());
}

#[test]
fn concurrent_submits_racing_shutdown_resolve_without_hanging() {
    use flashlight::serve::{Batcher, BatcherConfig};
    let session = InferenceSession::compile(&[2], DType::F32, &[1, 4], |x| x.tanh()).unwrap();
    let cfg = BatcherConfig {
        max_batch_size: 4,
        max_wait: Duration::from_millis(1),
        workers: 1,
    };
    let batcher = Arc::new(Batcher::start(Arc::new(session), cfg));

    std::thread::scope(|s| {
        let submitters: Vec<_> = (0..4)
            .map(|t| {
                let b = Arc::clone(&batcher);
                s.spawn(move || {
                    let mut outcomes = Vec::new();
                    for i in 0..25 {
                        let x = Tensor::from_slice(&[t as f32, i as f32], [2]);
                        outcomes.push((t, i, b.submit(x).wait()));
                    }
                    outcomes
                })
            })
            .collect();
        // shut down while the submitters are mid-flight: every handle must
        // still resolve — served bitwise-correctly or rejected cleanly
        std::thread::sleep(Duration::from_millis(5));
        batcher.shutdown();
        let mut served = 0usize;
        let mut total = 0usize;
        for handle in submitters {
            for (t, i, outcome) in handle.join().unwrap() {
                total += 1;
                if let Ok(y) = outcome {
                    served += 1;
                    let x = Tensor::from_slice(&[t as f32, i as f32], [2]);
                    assert_eq!(bits(&y.to_vec()), bits(&x.tanh().to_vec()));
                }
            }
        }
        assert_eq!(total, 100, "every submit must resolve, racing shutdown or not");
        assert!(served > 0, "requests queued before shutdown must still be served");
    });
}

#[test]
fn concurrent_generate_submits_racing_shutdown_resolve_without_hanging() {
    let model = Arc::new(small_lm(24, 24));
    let cfg = ContinuousConfig { max_active: 3, page_tokens: 4, pool_pages: None, ..def() };
    let batcher = Arc::new(ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap());

    std::thread::scope(|s| {
        let submitters: Vec<_> = (0u64..3)
            .map(|t| {
                let b = Arc::clone(&batcher);
                let m = Arc::clone(&model);
                s.spawn(move || {
                    let mut outcomes = 0usize;
                    for i in 0u64..8 {
                        let prompt = [t as i64, i as i64, 3];
                        let opts = gen_opts(t * 31 + i, 3, Sampling::Greedy);
                        if let Ok(served) = b.generate(&prompt, &opts) {
                            assert_report_matches_solo(&m, &prompt, &opts, &served, "racing");
                        }
                        outcomes += 1;
                    }
                    outcomes
                })
            })
            .collect();
        std::thread::sleep(Duration::from_millis(10));
        batcher.shutdown();
        let total: usize = submitters.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(total, 24, "every generate must resolve, racing shutdown or not");
    });
}

// ---- session-level behavior ----------------------------------------------

#[test]
fn session_buckets_validate_and_route() {
    let session = InferenceSession::compile(&[3], DType::F32, &[2, 4, 1], |x| {
        x.mul(x).add_scalar(1.0)
    })
    .unwrap();
    assert_eq!(session.bucket_sizes(), vec![1, 2, 4]);
    assert_eq!(session.max_batch(), 4);
    assert_eq!(session.bucket_for(3), Some(4));
    assert_eq!(session.bucket_for(5), None);
    assert_eq!(session.output_dims(), &[3]);

    // routing pads 3 rows into the 4-bucket and slices back
    let batch = Tensor::rand([3, 3], -1.0, 1.0);
    let out = session.run_batch(batch.copy()).unwrap();
    assert_eq!(out.dims(), &[3, 3]);
    let direct = batch.mul(&batch).add_scalar(1.0);
    assert_eq!(bits(&out.to_vec()), bits(&direct.to_vec()));

    // a single example loses its batch axis
    let one = session.run_one(Tensor::rand([3], -1.0, 1.0)).unwrap();
    assert_eq!(one.dims(), &[3]);

    // oversized batches, wrong dtypes, and wrong shapes are rejected
    assert!(session.run_batch(Tensor::rand([5, 3], -1.0, 1.0)).is_err());
    assert!(session.run_batch(Tensor::rand([2, 4], -1.0, 1.0)).is_err());
    assert!(session
        .run_batch(Tensor::rand([2, 3], 0.0, 1.0).astype(DType::I64))
        .is_err());
    // and so are degenerate bucket lists
    assert!(InferenceSession::compile(&[3], DType::F32, &[], |x| x.copy()).is_err());
    assert!(InferenceSession::compile(&[3], DType::F32, &[0], |x| x.copy()).is_err());
    // a non-batch-major forward is caught at compile time
    assert!(
        InferenceSession::compile(&[3], DType::F32, &[2], |x| x.sum(&[], false)).is_err(),
        "reducing away the batch axis must be rejected"
    );
}

#[test]
fn steady_state_serving_does_not_retrace() {
    // trace_and_compile runs the forward closure exactly once per bucket;
    // count invocations to prove steady-state serving never re-traces
    use std::sync::atomic::{AtomicUsize, Ordering};
    let traces = Arc::new(AtomicUsize::new(0));
    let counter = Arc::clone(&traces);
    let session = InferenceSession::compile(&[2], DType::F32, &[1, 2], move |x| {
        counter.fetch_add(1, Ordering::SeqCst);
        x.tanh()
    })
    .unwrap();
    assert_eq!(traces.load(Ordering::SeqCst), 2, "one trace per bucket");
    for i in 0..50 {
        let x = Tensor::from_slice(&[i as f32, -0.5 * i as f32], [2]);
        let y = session.run_one(x.copy()).unwrap();
        assert_eq!(bits(&y.to_vec()), bits(&x.tanh().to_vec()));
    }
    assert_eq!(traces.load(Ordering::SeqCst), 2, "serving must not re-trace");
}

// ---- bucket-compiled decode iterations + chunked prefill -------------------

/// Prefill `prompt` into a fresh cache on `pool` (reserving room for
/// `max_new` decode steps) — one per-request stream for the decode-step
/// parity tests below.
fn prefilled_cache(
    model: &BertLike,
    pool: &Arc<KvPagePool>,
    prompt: &[i64],
    max_new: usize,
) -> PagedKvCache {
    let mut cache = PagedKvCache::new(Arc::clone(pool));
    cache.reserve(prompt.len() + max_new).expect("test pool sized for the request");
    let ids = Tensor::from_slice(prompt, [1, prompt.len()]);
    no_grad(|| model.logits_paged(&ids, &mut cache));
    cache
}

#[test]
fn compiled_decode_step_bit_identical_to_eager_exact_and_padded() {
    let model = small_lm(32, 48);
    // one step with an exact-fit bucket, one that must pad 3 rows into 4
    let exact = CompiledDecodeStep::compile(&model, &[3]).unwrap();
    let padded = CompiledDecodeStep::compile(&model, &[4]).unwrap();
    assert_eq!(exact.bucket_sizes(), vec![3]);
    assert_eq!(exact.program_count(), model.depth() + 1, "depth+1 segments per bucket");

    // three cache sets fed identical tokens: eager reference, exact
    // bucket, padded bucket — all three must stay bitwise locked
    let pools: Vec<Arc<KvPagePool>> =
        (0..3).map(|_| KvPagePool::new(model.kv_pool_config(4, 24))).collect();
    let mut rng = Rng::new(17);
    let prompts: Vec<Vec<i64>> = (0..3).map(|r| random_ids(&mut rng, 3 + r, 32)).collect();
    let mut sets: Vec<Vec<PagedKvCache>> = pools
        .iter()
        .map(|pool| prompts.iter().map(|p| prefilled_cache(&model, pool, p, 6)).collect())
        .collect();
    let mut tokens: Vec<i64> = prompts.iter().map(|p| p[0]).collect();

    for t in 0..5 {
        let ids = Tensor::from_slice(&tokens, [3, 1]);
        let [eager_set, exact_set, padded_set] = &mut sets[..] else { unreachable!() };
        let mut refs: Vec<&mut PagedKvCache> = eager_set.iter_mut().collect();
        let want = no_grad(|| model.logits_decode_batch(&ids, &mut refs)).tensor();
        let mut refs: Vec<&mut PagedKvCache> = exact_set.iter_mut().collect();
        let got_exact = no_grad(|| exact.step(&model, &tokens, &mut refs))
            .unwrap()
            .expect("batch 3 fits the 3-bucket");
        let mut refs: Vec<&mut PagedKvCache> = padded_set.iter_mut().collect();
        let got_padded = no_grad(|| padded.step(&model, &tokens, &mut refs))
            .unwrap()
            .expect("batch 3 fits the 4-bucket");
        assert_eq!(got_exact.dims(), want.dims());
        assert_eq!(got_padded.dims(), want.dims(), "pad rows must be sliced off");
        let want_bits = bits(&want.to_vec());
        assert_eq!(bits(&got_exact.to_vec()), want_bits, "exact bucket diverged at step {t}");
        assert_eq!(bits(&got_padded.to_vec()), want_bits, "padded bucket diverged at step {t}");
        // feed the (identical) greedy tokens back so the streams extend
        let v = model.vocab();
        let flat = want.to_vec();
        for r in 0..3 {
            tokens[r] = argmax(&flat[r * v..(r + 1) * v]);
        }
    }
    for set in &sets[1..] {
        for (c, e) in set.iter().zip(&sets[0]) {
            assert_eq!(c.len(), e.len(), "compiled steps must advance caches like eager");
        }
    }
}

#[test]
fn compiled_decode_step_misses_oversized_batches_without_touching_caches() {
    let model = small_lm(24, 32);
    let step = CompiledDecodeStep::compile(&model, &[1, 2]).unwrap();
    assert_eq!(step.bucket_sizes(), vec![1, 2]);
    let pool = KvPagePool::new(model.kv_pool_config(4, 24));
    let mut caches: Vec<PagedKvCache> =
        (0..3).map(|r| prefilled_cache(&model, &pool, &[r as i64 + 1, 2], 4)).collect();
    let lens: Vec<usize> = caches.iter().map(|c| c.len()).collect();
    let mut refs: Vec<&mut PagedKvCache> = caches.iter_mut().collect();
    let out = no_grad(|| step.step(&model, &[5, 6, 7], &mut refs)).unwrap();
    assert!(out.is_none(), "batch 3 exceeds every bucket: an observable compile miss");
    for (c, l) in refs.iter().zip(&lens) {
        assert_eq!(c.len(), *l, "a miss must leave the caches untouched for the eager retry");
    }
    // a batch that does fit still routes and advances
    let mut refs: Vec<&mut PagedKvCache> = caches.iter_mut().take(2).collect();
    let out = no_grad(|| step.step(&model, &[5, 6], &mut refs)).unwrap().expect("2 fits");
    assert_eq!(out.dims(), &[2, 1, model.vocab()][..]);
    assert_eq!(caches[0].len(), lens[0] + 1);
    assert_eq!(caches[2].len(), lens[2], "rows outside the batch must not advance");
    // degenerate bucket lists are rejected up front
    assert!(CompiledDecodeStep::compile(&model, &[]).is_err());
    assert!(CompiledDecodeStep::compile(&model, &[0]).is_err());
}

#[test]
fn chunked_prefill_stays_bitwise_and_counts_chunks() {
    let model = Arc::new(small_lm(48, 64));
    let cfg = ContinuousConfig {
        max_active: 3,
        page_tokens: 4,
        pool_pages: None,
        prefill_chunk: Some(3),
        ..def()
    };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();
    let mut rng = Rng::new(23);
    // prompt lengths straddling the chunk size: 7, 10, and 5 split
    let lens = [2usize, 7, 3, 10, 5];
    let requests: Vec<(Vec<i64>, GenerateOptions)> = lens
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            let prompt = random_ids(&mut rng, n, 48);
            let sampling = if i % 2 == 0 {
                Sampling::Greedy
            } else {
                Sampling::TopK { k: 5, temperature: 0.9 }
            };
            (prompt, gen_opts(300 + i as u64, 3 + i, sampling))
        })
        .collect();
    let handles: Vec<_> = requests.iter().map(|(p, o)| batcher.submit(p, o)).collect();
    for ((prompt, opts), handle) in requests.iter().zip(handles) {
        let served = handle.wait().unwrap();
        assert_report_matches_solo(&model, prompt, opts, &served, "chunked-prefill");
        assert_eq!(
            served.prefill_chunks,
            prompt.len().div_ceil(3),
            "prefill pass count for a {}-token prompt at chunk 3",
            prompt.len()
        );
    }
    let stats = batcher.stats();
    assert_eq!(stats.prefills, 5, "every admission runs a prefill, chunked or not");
    assert_eq!(stats.chunked_admissions, 3, "prompts of 7, 10, and 5 tokens split at chunk 3");
    assert_eq!(stats.prefill_chunks, 1 + 3 + 1 + 4 + 2);
    assert_eq!(stats.compiled_iterations + stats.compile_misses, stats.iterations);
    assert_eq!(stats.pool.leased_pages, 0);
    batcher.shutdown();
}

#[test]
fn compiled_decode_telemetry_proves_zero_steady_state_retracing() {
    let model = Arc::new(small_lm(32, 48));
    // auto buckets for max_active 4 are {1, 2, 4}: every feasible batch
    // size fits one, so the run can never miss
    let cfg = ContinuousConfig { max_active: 4, page_tokens: 4, pool_pages: None, ..def() };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();
    let segs = (model.depth() + 1) as u64;
    let compiles = batcher.stats().decode_compiles;
    assert_eq!(compiles, 3 * segs, "buckets {{1,2,4}} x (depth+1) segment programs");
    let opts = gen_opts(5, 6, Sampling::Greedy);
    let handles: Vec<_> = (0..6).map(|i| batcher.submit(&[1 + i as i64, 2, 3], &opts)).collect();
    for h in handles {
        h.wait().unwrap();
    }
    let stats = batcher.stats();
    assert!(stats.iterations > 0);
    assert_eq!(stats.compile_misses, 0, "auto buckets must cover every batch size");
    assert_eq!(stats.compiled_iterations, stats.iterations);
    assert_eq!(stats.decode_compiles, compiles, "steady state must not compile anything new");
    batcher.shutdown();

    // disabling compiled decode turns every iteration into a counted
    // miss — and the eager fallback keeps the same bits
    let cfg = ContinuousConfig { decode_buckets: Some(vec![]), ..cfg };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();
    let served = batcher.generate(&[4, 2, 7], &opts).unwrap();
    assert_report_matches_solo(&model, &[4, 2, 7], &opts, &served, "eager-only");
    let stats = batcher.stats();
    assert_eq!(stats.decode_compiles, 0);
    assert_eq!(stats.compiled_iterations, 0);
    assert!(stats.iterations > 0);
    assert_eq!(stats.compile_misses, stats.iterations);
    batcher.shutdown();
}

#[test]
fn narrow_buckets_count_misses_and_still_serve_bitwise() {
    let model = Arc::new(small_lm(32, 48));
    let cfg = ContinuousConfig {
        max_active: 4,
        page_tokens: 4,
        pool_pages: None,
        decode_buckets: Some(vec![1]),
        ..def()
    };
    let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg).unwrap();
    assert_eq!(batcher.stats().decode_compiles, (model.depth() + 1) as u64);
    // budgets 4/8/12/16: retirements stagger, so the tail drains down to
    // solo (bucket-sized) iterations while the shared middle misses
    let requests: Vec<(Vec<i64>, GenerateOptions)> = (0..4)
        .map(|i| (vec![3 + i as i64, 1, 4], gen_opts(40 + i as u64, 4 + 4 * i, Sampling::Greedy)))
        .collect();
    let handles: Vec<_> = requests.iter().map(|(p, o)| batcher.submit(p, o)).collect();
    for ((prompt, opts), handle) in requests.iter().zip(handles) {
        let served = handle.wait().unwrap();
        assert_report_matches_solo(&model, prompt, opts, &served, "narrow-buckets");
    }
    let stats = batcher.stats();
    assert_eq!(stats.compiled_iterations + stats.compile_misses, stats.iterations);
    assert!(stats.compiled_iterations > 0, "the drained tail decodes solo through the 1-bucket");
    assert!(stats.compile_misses > 0, "shared iterations exceed the only bucket");
    batcher.shutdown();
}

#[test]
fn solo_generate_reports_prefill_chunks() {
    let model = small_lm(24, 24);
    let cached = generate(&model, &[1, 2, 3], &gen_opts(1, 3, Sampling::Greedy)).unwrap();
    assert_eq!(cached.prefill_chunks, 1, "one whole-prompt prefill pass");
    let uncached = GenerateOptions { use_cache: false, ..gen_opts(1, 3, Sampling::Greedy) };
    let r = generate(&model, &[1, 2, 3], &uncached).unwrap();
    assert_eq!(r.prefill_chunks, 0, "the uncached path has no prefill");
    assert_eq!(r.tokens, cached.tokens, "cached and uncached streams agree");
}
