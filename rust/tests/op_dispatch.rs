//! Exhaustiveness tests for the Op IR: every `Op` variant round-trips
//! through `TensorBackend::dispatch` on the CPU backend and is
//! **bit-identical** to the direct typed method call. The coverage set is
//! checked against `Op::ALL_NAMES`, so a new variant without a round-trip
//! case fails here (and a variant that `execute` forgets to route fails
//! to compile in the first place).
//!
//! These tests install no backend guards, so the ambient default backend
//! stays the reference CPU backend for the whole process.

use flashlight::tensor::cpu::CpuBackend;
use flashlight::tensor::{
    Conv2dParams, DType, HostBuffer, Op, Pool2dParams, PoolKind, Shape, Tensor, TensorBackend,
};

type TypedFn = Box<dyn Fn(&dyn TensorBackend, &[&Tensor]) -> Tensor>;

struct Case {
    op: Op,
    inputs: Vec<Tensor>,
    typed: TypedFn,
}

fn t(v: &[f32], dims: &[usize]) -> Tensor {
    Tensor::from_slice(v, dims.to_vec())
}

fn bools(v: &[u8], dims: &[usize]) -> Tensor {
    Tensor::from_host(HostBuffer::U8(v.to_vec(), true), dims.to_vec())
}

fn ramp(n: usize, scale: f32, shift: f32) -> Vec<f32> {
    (0..n).map(|i| i as f32 * scale + shift).collect()
}

#[allow(clippy::too_many_lines)]
fn cases() -> Vec<Case> {
    // deterministic operands; domains chosen so every op is NaN-free
    // except the dedicated `isnan` probe (NaN would defeat the
    // bit-identity comparison, which uses `PartialEq` on host buffers)
    let a = t(&[0.5, -1.5, 2.0, 3.25, -0.25, 1.0], &[2, 3]);
    let pos = t(&[0.5, 1.5, 2.0, 3.25, 0.25, 1.0], &[2, 3]);
    let b = t(&[2.0, 0.5, 1.0, 4.0, 2.5, 0.5], &[2, 3]);
    let with_nan = t(&[1.0, f32::NAN, 0.0, -2.0, 5.5, f32::NAN], &[2, 3]);
    let bool1 = bools(&[1, 0, 1, 0, 1, 1], &[2, 3]);
    let bool2 = bools(&[1, 1, 0, 0, 1, 0], &[2, 3]);
    let m1 = t(&ramp(6, 0.5, -1.0), &[2, 3]);
    let m2 = t(&ramp(6, -0.25, 1.0), &[3, 2]);
    let idx = Tensor::from_slice(&[1i64, 0], [2]);
    let conv_x = t(&ramp(32, 0.125, -2.0), &[1, 2, 4, 4]);
    let conv_w = t(&ramp(36, 0.05, -0.8), &[2, 2, 3, 3]);
    let conv_gy = t(&ramp(32, -0.1, 1.5), &[1, 2, 4, 4]);
    let cp = Conv2dParams { stride: (1, 1), padding: (1, 1) };
    let pool_x = t(&ramp(16, 0.3, -2.0), &[1, 1, 4, 4]);
    let pool_gy = t(&ramp(4, 0.5, 1.0), &[1, 1, 2, 2]);
    let pp = Pool2dParams { kind: PoolKind::Max, kernel: (2, 2), stride: (2, 2) };
    let host = HostBuffer::F32(vec![1.0, -2.0, 3.5]);

    let mut v: Vec<Case> = Vec::new();

    macro_rules! unary {
        ($inp:expr, $variant:ident, $meth:ident) => {
            v.push(Case {
                op: Op::$variant,
                inputs: vec![$inp.clone()],
                typed: Box::new(|be, i| be.$meth(i[0])),
            });
        };
    }
    macro_rules! binary {
        ($x:expr, $y:expr, $variant:ident, $meth:ident) => {
            v.push(Case {
                op: Op::$variant,
                inputs: vec![$x.clone(), $y.clone()],
                typed: Box::new(|be, i| be.$meth(i[0], i[1])),
            });
        };
    }
    macro_rules! reduce {
        ($inp:expr, $variant:ident, $meth:ident) => {
            v.push(Case {
                op: Op::$variant { axes: vec![1], keepdims: false },
                inputs: vec![$inp.clone()],
                typed: Box::new(|be, i| be.$meth(i[0], &[1], false)),
            });
        };
    }

    // creation
    v.push(Case {
        op: Op::Full { shape: Shape::new(vec![2, 2]), value: 3.5, dtype: DType::F32 },
        inputs: vec![],
        typed: Box::new(|be, _| be.full(&Shape::new(vec![2, 2]), 3.5, DType::F32)),
    });
    v.push(Case {
        op: Op::Arange { n: 5, dtype: DType::I64 },
        inputs: vec![],
        typed: Box::new(|be, _| be.arange(5, DType::I64)),
    });
    {
        let h = host.clone();
        v.push(Case {
            op: Op::FromHost { host: host.clone(), shape: Shape::new(vec![3]) },
            inputs: vec![],
            typed: Box::new(move |be, _| be.from_host(h.clone(), Shape::new(vec![3]))),
        });
    }

    // unary
    unary!(a, Neg, neg);
    unary!(a, Abs, abs);
    unary!(a, Sign, sign);
    unary!(a, Exp, exp);
    unary!(pos, Log, log);
    unary!(pos, Log1p, log1p);
    unary!(a, Sin, sin);
    unary!(a, Cos, cos);
    unary!(a, Tanh, tanh);
    unary!(pos, Sqrt, sqrt);
    unary!(pos, Rsqrt, rsqrt);
    unary!(pos, Reciprocal, reciprocal);
    unary!(a, Floor, floor);
    unary!(a, Ceil, ceil);
    unary!(a, Round, round);
    unary!(a, Erf, erf);
    unary!(bool1, LogicalNot, logical_not);
    unary!(with_nan, IsNan, isnan);
    v.push(Case {
        op: Op::Clip { lo: -1.0, hi: 2.0 },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.clip(i[0], -1.0, 2.0)),
    });

    // binary + comparison
    binary!(a, b, Add, add);
    binary!(a, b, Sub, sub);
    binary!(a, b, Mul, mul);
    binary!(a, b, Div, div);
    binary!(pos, b, Pow, pow);
    binary!(a, b, Minimum, minimum);
    binary!(a, b, Maximum, maximum);
    binary!(a, b, Rem, rem);
    binary!(a, b, Eq, eq);
    binary!(a, b, Neq, neq);
    binary!(a, b, Lt, lt);
    binary!(a, b, Le, le);
    binary!(a, b, Gt, gt);
    binary!(a, b, Ge, ge);
    binary!(bool1, bool2, LogicalAnd, logical_and);
    binary!(bool1, bool2, LogicalOr, logical_or);

    // reductions
    reduce!(a, Sum, sum);
    reduce!(a, Prod, prod);
    reduce!(a, MaxReduce, max_reduce);
    reduce!(a, MinReduce, min_reduce);
    reduce!(bool1, Any, any);
    reduce!(bool1, All, all);
    v.push(Case {
        op: Op::Argmax { axis: 1, keepdims: false },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.argmax(i[0], 1, false)),
    });
    v.push(Case {
        op: Op::Argmin { axis: 1, keepdims: false },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.argmin(i[0], 1, false)),
    });
    v.push(Case {
        op: Op::Cumsum { axis: 1 },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.cumsum(i[0], 1)),
    });

    // linear algebra + nn
    binary!(m1, m2, Matmul, matmul);
    v.push(Case {
        op: Op::Conv2d(cp),
        inputs: vec![conv_x.clone(), conv_w.clone()],
        typed: Box::new(move |be, i| be.conv2d(i[0], i[1], cp)),
    });
    v.push(Case {
        op: Op::Conv2dBwdInput { x_shape: Shape::new(vec![1, 2, 4, 4]), params: cp },
        inputs: vec![conv_gy.clone(), conv_w.clone()],
        typed: Box::new(move |be, i| {
            be.conv2d_bwd_input(i[0], i[1], &Shape::new(vec![1, 2, 4, 4]), cp)
        }),
    });
    v.push(Case {
        op: Op::Conv2dBwdFilter { w_shape: Shape::new(vec![2, 2, 3, 3]), params: cp },
        inputs: vec![conv_gy.clone(), conv_x.clone()],
        typed: Box::new(move |be, i| {
            be.conv2d_bwd_filter(i[0], i[1], &Shape::new(vec![2, 2, 3, 3]), cp)
        }),
    });
    v.push(Case {
        op: Op::Pool2d(pp),
        inputs: vec![pool_x.clone()],
        typed: Box::new(move |be, i| be.pool2d(i[0], pp)),
    });
    v.push(Case {
        op: Op::Pool2dBwd(pp),
        inputs: vec![pool_gy.clone(), pool_x.clone()],
        typed: Box::new(move |be, i| be.pool2d_bwd(i[0], i[1], pp)),
    });

    // data movement
    v.push(Case {
        op: Op::Reshape { shape: Shape::new(vec![3, 2]) },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.reshape(i[0], &Shape::new(vec![3, 2]))),
    });
    v.push(Case {
        op: Op::Transpose { perm: vec![1, 0] },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.transpose(i[0], &[1, 0])),
    });
    v.push(Case {
        op: Op::Slice { starts: vec![0, 1], ends: vec![2, 3] },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.slice(i[0], &[0, 1], &[2, 3])),
    });
    v.push(Case {
        op: Op::Concat { axis: 0 },
        inputs: vec![a.clone(), b.clone()],
        typed: Box::new(|be, i| be.concat(i, 0)),
    });
    v.push(Case {
        op: Op::Pad { pads: vec![(1, 0), (0, 2)], value: 0.5 },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.pad(i[0], &[(1, 0), (0, 2)], 0.5)),
    });
    v.push(Case {
        op: Op::Tile { reps: vec![2, 1] },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.tile(i[0], &[2, 1])),
    });
    v.push(Case {
        op: Op::Flip { axes: vec![1] },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.flip(i[0], &[1])),
    });
    v.push(Case {
        op: Op::IndexSelect { axis: 0 },
        inputs: vec![a.clone(), idx.clone()],
        typed: Box::new(|be, i| be.index_select(i[0], 0, i[1])),
    });
    v.push(Case {
        op: Op::ScatterAdd,
        inputs: vec![a.clone(), idx.clone(), b.clone()],
        typed: Box::new(|be, i| be.scatter_add(i[0], i[1], i[2])),
    });
    v.push(Case {
        op: Op::WhereCond,
        inputs: vec![bool1.clone(), a.clone(), b.clone()],
        typed: Box::new(|be, i| be.where_cond(i[0], i[1], i[2])),
    });
    v.push(Case {
        op: Op::Astype { dtype: DType::I32 },
        inputs: vec![a.clone()],
        typed: Box::new(|be, i| be.astype(i[0], DType::I32)),
    });
    unary!(a, Copy, copy);

    v
}

#[test]
fn every_op_variant_round_trips_bit_identically() {
    let cpu = CpuBackend::shared();
    let mut covered = std::collections::HashSet::new();
    for case in cases() {
        let name = case.op.name();
        let ins: Vec<&Tensor> = case.inputs.iter().collect();
        let via_dispatch = cpu
            .dispatch(&case.op, &ins)
            .unwrap_or_else(|e| panic!("dispatch of `{name}` failed: {e}"));
        let direct = (case.typed)(cpu.as_ref(), &ins);
        assert_eq!(via_dispatch.dtype(), direct.dtype(), "dtype mismatch for `{name}`");
        assert!(
            via_dispatch.shape() == direct.shape(),
            "shape mismatch for `{name}`: {} vs {}",
            via_dispatch.shape(),
            direct.shape()
        );
        assert_eq!(
            via_dispatch.to_host(),
            direct.to_host(),
            "op `{name}` is not bit-identical through dispatch"
        );
        covered.insert(name);
    }

    // the three op kinds verified by the dedicated tests below
    covered.insert("rand_uniform");
    covered.insert("rand_normal");
    covered.insert("call_ext");

    for name in Op::ALL_NAMES {
        assert!(covered.contains(name), "no round-trip case for op `{name}`");
    }
    assert_eq!(
        covered.len(),
        Op::ALL_NAMES.len(),
        "cases cover ops missing from Op::ALL_NAMES"
    );
}

#[test]
fn rand_ops_dispatch_with_correct_metadata() {
    // RNG ops advance the stream on every draw, so two executions are
    // never bit-identical by design; verify shape/dtype/support instead.
    let cpu = CpuBackend::shared();
    let u = cpu
        .dispatch(
            &Op::RandUniform { shape: Shape::new(vec![3, 4]), lo: -1.0, hi: 1.0, dtype: DType::F32 },
            &[],
        )
        .unwrap();
    assert_eq!(u.dims(), &[3, 4]);
    assert_eq!(u.dtype(), DType::F32);
    assert!(u.to_vec().iter().all(|&x| (-1.0..1.0).contains(&x)));

    let n = cpu
        .dispatch(
            &Op::RandNormal { shape: Shape::new(vec![8]), mean: 0.0, std: 1.0, dtype: DType::F32 },
            &[],
        )
        .unwrap();
    assert_eq!(n.dims(), &[8]);
    assert_eq!(n.dtype(), DType::F32);
}

#[test]
fn call_ext_round_trips_the_error_contract() {
    let cpu = CpuBackend::shared();
    let via_dispatch = cpu.dispatch(&Op::CallExt { name: "missing_kernel".into() }, &[]);
    let direct = cpu.call_ext("missing_kernel", &[]);
    assert!(via_dispatch.is_err() && direct.is_err());
    assert_eq!(via_dispatch.unwrap_err().to_string(), direct.unwrap_err().to_string());
}
