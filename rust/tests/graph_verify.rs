//! Mutation testing for the static graph verifier.
//!
//! The verifier's contract has two halves, and this suite proves both:
//!
//! - **100% kill rate**: every seeded miscompile class below — operand
//!   rewires, dropped/reordered effects, illegal fusion, memory-plan
//!   corruption, output swaps — must be flagged by
//!   [`verify::verify_program`] with the *expected* [`DiagnosticKind`],
//!   for every generated program where the class applies. A mutant that
//!   survives fails the test.
//! - **Zero false positives**: clean programs (random traces compiled
//!   under every pass configuration, `FL_VERIFY=1` so each pass is also
//!   re-checked inside `compile`) must verify with zero diagnostics.
//!
//! Mutants are built by corrupting a *compiled* clean program the way a
//! buggy pass would: instruction-level mutants rebuild the memory plan
//! (the bug is in the dataflow, the plan honestly reflects it), while
//! plan-level mutants corrupt the plan directly (the dataflow is fine,
//! the planner lied). Hand-built minimal negatives pin the exact
//! `(kind, instr)` each diagnostic reports.
//!
//! Knobs: `GRAPH_VERIFY_CASES` (cases per sweep, default 120; CI runs
//! more), `GRAPH_VERIFY_SEED` (pin one case for replay).

use std::collections::BTreeMap;

use flashlight::tensor::graph::fuse::{FusedArg, FusedKernel};
use flashlight::tensor::graph::memplan::MemoryPlan;
use flashlight::tensor::graph::verify::{self, DiagnosticKind, SourceSpec, VerifiedMeta};
use flashlight::tensor::graph::{
    compile, CompileOptions, CompiledInstr, CompiledProgram, Graph, Node,
};
use flashlight::tensor::trace::{TraceInstr, TraceProgram, ValueRef};
use flashlight::tensor::{DType, HostBuffer, Op, Shape, Tensor};
use flashlight::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

// ---------------------------------------------------------------------------
// program generator
// ---------------------------------------------------------------------------

fn from_host(rng: &mut Rng, dims: &[usize], salt: f32) -> Op {
    let n: usize = dims.iter().product();
    let data: Vec<f32> =
        (0..n.max(1)).map(|k| salt + k as f32 * 0.25 + rng.below(16) as f32 * 0.01).collect();
    Op::FromHost { host: HostBuffer::F32(data), shape: Shape::new(dims.to_vec()) }
}

fn broadcast(a: &[usize], b: &[usize]) -> Option<Vec<usize>> {
    let r = a.len().max(b.len());
    let mut out = vec![0usize; r];
    for i in 0..r {
        let x = if i < r - a.len() { 1 } else { a[i - (r - a.len())] };
        let y = if i < r - b.len() { 1 } else { b[i - (r - b.len())] };
        out[i] = match (x, y) {
            (x, y) if x == y => x,
            (1, y) => y,
            (x, 1) => x,
            _ => return None,
        };
    }
    Some(out)
}

/// One random trace around a deterministic skeleton that guarantees
/// every mutation class below is applicable: f32 seeds of
/// broadcast-compatible shapes, a `[4]`-shaped outlier (incompatible
/// with the `[2, 3]` family), a non-f32 value, one dead and one live
/// effectful op, a fusible element-wise chain through a constant, and a
/// fusion-breaking reduction before the outputs.
fn gen_program(rng: &mut Rng) -> (TraceProgram, Vec<ValueRef>) {
    let mut instrs: Vec<TraceInstr> = Vec::new();
    let mut push = |instrs: &mut Vec<TraceInstr>, op: Op, inputs: Vec<ValueRef>| -> usize {
        instrs.push(TraceInstr { op, inputs });
        instrs.len() - 1
    };
    let a = push(&mut instrs, from_host(rng, &[2, 3], 1.0), vec![]);
    let b = push(&mut instrs, from_host(rng, &[2, 3], 2.0), vec![]);
    let q = push(&mut instrs, from_host(rng, &[4], 3.0), vec![]); // family outlier
    let casted = push(&mut instrs, Op::Astype { dtype: DType::I64 }, vec![ValueRef::Out(a)]);
    let _dead = push(
        &mut instrs,
        Op::RandUniform { shape: Shape::new(vec![2, 3]), lo: 0.0, hi: 1.0, dtype: DType::F32 },
        vec![],
    );
    let live = push(
        &mut instrs,
        Op::RandUniform { shape: Shape::new(vec![2, 3]), lo: 1.0, hi: 2.0, dtype: DType::F32 },
        vec![],
    );
    let s1 = push(&mut instrs, Op::Add, vec![ValueRef::Out(a), ValueRef::Out(b)]);
    let s2 = push(&mut instrs, Op::Neg, vec![ValueRef::Out(s1)]);
    let s3 = push(&mut instrs, Op::Add, vec![ValueRef::Out(s2), ValueRef::Const(0)]);
    let s4 = push(&mut instrs, Op::Add, vec![ValueRef::Out(live), ValueRef::Out(s3)]);
    // random tail over the broadcast-compatible f32 pool
    let mut pool: Vec<(usize, Vec<usize>)> = vec![
        (a, vec![2, 3]),
        (b, vec![2, 3]),
        (live, vec![2, 3]),
        (s1, vec![2, 3]),
        (s2, vec![2, 3]),
        (s3, vec![2, 3]),
        (s4, vec![2, 3]),
    ];
    for _ in 0..rng.below(6) {
        match rng.below(3) {
            0 => {
                // binary over a broadcast-compatible pair (retry a few draws)
                for _ in 0..10 {
                    let (x, sx) = pool[rng.below(pool.len())].clone();
                    let (y, sy) = pool[rng.below(pool.len())].clone();
                    if let Some(sz) = broadcast(&sx, &sy) {
                        let op = match rng.below(5) {
                            0 => Op::Add,
                            1 => Op::Sub,
                            2 => Op::Mul,
                            3 => Op::Maximum,
                            _ => Op::Minimum,
                        };
                        let v =
                            push(&mut instrs, op, vec![ValueRef::Out(x), ValueRef::Out(y)]);
                        pool.push((v, sz));
                        break;
                    }
                }
            }
            1 => {
                let (x, sx) = pool[rng.below(pool.len())].clone();
                let op = match rng.below(3) {
                    0 => Op::Neg,
                    1 => Op::Abs,
                    _ => Op::Exp,
                };
                let v = push(&mut instrs, op, vec![ValueRef::Out(x)]);
                pool.push((v, sx));
            }
            _ => {
                let (x, sx) = pool[rng.below(pool.len())].clone();
                let ax = rng.below(2);
                let mut sz = sx.clone();
                if ax < sz.len() {
                    sz.remove(ax);
                }
                let v = push(
                    &mut instrs,
                    Op::Sum { axes: vec![ax], keepdims: false },
                    vec![ValueRef::Out(x)],
                );
                pool.push((v, sz));
            }
        }
    }
    let red =
        push(&mut instrs, Op::Sum { axes: vec![0], keepdims: false }, vec![ValueRef::Out(s4)]);
    let qq = push(&mut instrs, Op::Abs, vec![ValueRef::Out(q)]);
    let mut outputs = vec![ValueRef::Out(red), ValueRef::Out(qq), ValueRef::Out(casted)];
    if rng.below(2) == 0 {
        outputs.push(ValueRef::Out(pool[rng.below(pool.len())].0));
    }
    let consts = vec![Tensor::full(vec![2, 3], 0.5, DType::F32)];
    (TraceProgram { consts, instrs }, outputs)
}

// ---------------------------------------------------------------------------
// mutation machinery
// ---------------------------------------------------------------------------

fn inputs_mut(instr: &mut CompiledInstr) -> &mut Vec<ValueRef> {
    match instr {
        CompiledInstr::Op { inputs, .. } => inputs,
        CompiledInstr::Fused(k) => &mut k.inputs,
    }
}

/// Rebuild the plan after an instruction-level mutation: the miscompile
/// is in the dataflow and the plan honestly reflects it.
fn rebuild(p: &mut CompiledProgram) {
    p.plan = MemoryPlan::build(&p.instrs, &p.outputs, p.consts.len());
}

/// Actual last-read positions (values, constants) from the instruction
/// stream — what a sound plan must respect.
fn last_reads(p: &CompiledProgram) -> (Vec<usize>, Vec<Option<usize>>) {
    let n = p.instrs.len();
    let mut lr: Vec<usize> = (0..n).collect();
    let mut clr: Vec<Option<usize>> = vec![None; p.consts.len()];
    for (j, instr) in p.instrs.iter().enumerate() {
        for r in instr.inputs() {
            match r {
                ValueRef::Out(i) if *i < j => lr[*i] = lr[*i].max(j),
                ValueRef::Const(c) if *c < p.consts.len() => clr[*c] = Some(j),
                _ => {}
            }
        }
    }
    (lr, clr)
}

fn ref_shape(r: &ValueRef, p: &CompiledProgram, meta: &VerifiedMeta) -> Option<Vec<usize>> {
    match r {
        ValueRef::Const(c) => Some(p.consts[*c].dims().to_vec()),
        ValueRef::Out(i) => meta.values[*i].as_ref().map(|m| m.shape.dims().to_vec()),
    }
}

/// Replay the verifier's left-fold broadcast over a kernel's steps with
/// the given input shapes: `true` if some step fails to broadcast.
fn fused_fold_fails(k: &FusedKernel, in_shapes: &[Option<Vec<usize>>]) -> bool {
    let mut steps: Vec<Option<Vec<usize>>> = Vec::with_capacity(k.steps.len());
    for step in &k.steps {
        let mut sh: Option<Vec<usize>> = None;
        for a in &step.args {
            let s = match a {
                FusedArg::Input(i) => in_shapes[*i].clone(),
                FusedArg::Step(t) => steps[*t].clone(),
            };
            sh = match (sh, s) {
                (None, s) => s,
                (s, None) => s,
                (Some(x), Some(y)) => match broadcast(&x, &y) {
                    Some(z) => Some(z),
                    None => return true,
                },
            };
        }
        steps.push(sh);
    }
    false
}

/// Rewire a binary op's second operand to an earlier value whose shape
/// cannot broadcast with the first operand's.
fn m_rewire_broadcast(p: &CompiledProgram, meta: &VerifiedMeta) -> Option<CompiledProgram> {
    for (j, instr) in p.instrs.iter().enumerate() {
        let CompiledInstr::Op { op, inputs } = instr else { continue };
        if !matches!(op, Op::Add | Op::Sub | Op::Mul | Op::Div | Op::Minimum | Op::Maximum) {
            continue;
        }
        let Some(s0) = ref_shape(&inputs[0], p, meta) else { continue };
        for i in 0..j {
            let Some(m) = meta.values[i].as_ref() else { continue };
            if broadcast(&s0, m.shape.dims()).is_none() {
                let mut q = p.clone();
                if let CompiledInstr::Op { inputs, .. } = &mut q.instrs[j] {
                    inputs[1] = ValueRef::Out(i);
                }
                rebuild(&mut q);
                return Some(q);
            }
        }
    }
    None
}

/// Delete a dead effectful op (no readers, not an output) the way an
/// effect-blind DCE would, remapping every later reference.
fn m_drop_effect(p: &CompiledProgram) -> Option<CompiledProgram> {
    'cand: for j in 0..p.instrs.len() {
        let CompiledInstr::Op { op, .. } = &p.instrs[j] else { continue };
        if !matches!(op, Op::RandUniform { .. }) {
            continue;
        }
        for instr in &p.instrs {
            if instr.inputs().iter().any(|r| matches!(r, ValueRef::Out(i) if *i == j)) {
                continue 'cand;
            }
        }
        if p.outputs.iter().any(|r| matches!(r, ValueRef::Out(i) if *i == j)) {
            continue;
        }
        let mut q = p.clone();
        q.instrs.remove(j);
        let remap = |r: &mut ValueRef| {
            if let ValueRef::Out(i) = r {
                if *i > j {
                    *i -= 1;
                }
            }
        };
        for instr in &mut q.instrs {
            for r in inputs_mut(instr).iter_mut() {
                remap(r);
            }
        }
        for r in &mut q.outputs {
            remap(r);
        }
        rebuild(&mut q);
        return Some(q);
    }
    None
}

/// Perturb an effectful op's payload (a miscompile CSE-style key reuse
/// could produce): same op kind, different distribution.
fn m_swap_effect_payload(p: &CompiledProgram) -> Option<CompiledProgram> {
    for j in 0..p.instrs.len() {
        if matches!(&p.instrs[j], CompiledInstr::Op { op: Op::RandUniform { .. }, .. }) {
            let mut q = p.clone();
            if let CompiledInstr::Op { op: Op::RandUniform { lo, hi, .. }, .. } =
                &mut q.instrs[j]
            {
                *lo -= 1.0;
                *hi += 1.0;
            }
            rebuild(&mut q);
            return Some(q);
        }
    }
    None
}

/// Rewire a fused kernel's input to an earlier non-f32 value.
fn m_fused_nonf32(p: &CompiledProgram, meta: &VerifiedMeta) -> Option<CompiledProgram> {
    for (j, instr) in p.instrs.iter().enumerate() {
        let CompiledInstr::Fused(k) = instr else { continue };
        if k.inputs.is_empty() {
            continue;
        }
        for i in 0..j {
            let Some(m) = meta.values[i].as_ref() else { continue };
            if m.dtype != DType::F32 {
                let mut q = p.clone();
                if let CompiledInstr::Fused(k) = &mut q.instrs[j] {
                    k.inputs[0] = ValueRef::Out(i);
                }
                rebuild(&mut q);
                return Some(q);
            }
        }
    }
    None
}

/// Rewire a fused kernel's input to an earlier f32 value whose shape
/// provably breaks the kernel's interior broadcast fold.
fn m_fused_broadcast(p: &CompiledProgram, meta: &VerifiedMeta) -> Option<CompiledProgram> {
    for (j, instr) in p.instrs.iter().enumerate() {
        let CompiledInstr::Fused(k) = instr else { continue };
        let shapes: Vec<Option<Vec<usize>>> =
            k.inputs.iter().map(|r| ref_shape(r, p, meta)).collect();
        for t in 0..k.inputs.len() {
            for i in 0..j {
                let Some(m) = meta.values[i].as_ref() else { continue };
                if m.dtype != DType::F32 {
                    continue;
                }
                let mut sh = shapes.clone();
                sh[t] = Some(m.shape.dims().to_vec());
                if fused_fold_fails(k, &sh) {
                    let mut q = p.clone();
                    if let CompiledInstr::Fused(k) = &mut q.instrs[j] {
                        k.inputs[t] = ValueRef::Out(i);
                    }
                    rebuild(&mut q);
                    return Some(q);
                }
            }
        }
    }
    None
}

/// Assign a later value the slot of a value that is live to the end.
fn m_alias_slot(p: &CompiledProgram) -> Option<CompiledProgram> {
    let n = p.instrs.len();
    for a in 0..n {
        if !p.plan.is_output.get(a).copied().unwrap_or(false) {
            continue;
        }
        for b in a + 1..n {
            if p.plan.slot[b] != p.plan.slot[a] {
                let mut q = p.clone();
                q.plan.slot[b] = p.plan.slot[a];
                return Some(q);
            }
        }
    }
    None
}

/// Rewire output 0 to an existing value with different static metadata.
fn m_output_swap(p: &CompiledProgram, meta: &VerifiedMeta) -> Option<CompiledProgram> {
    let want = meta.outputs.first().cloned().flatten()?;
    for i in 0..p.instrs.len() {
        if let Some(m) = meta.values[i].as_ref() {
            if *m != want {
                let mut q = p.clone();
                q.outputs[0] = ValueRef::Out(i);
                rebuild(&mut q);
                return Some(q);
            }
        }
    }
    None
}

/// Move a constant's donation frontier before its last actual read.
fn m_donate_early(p: &CompiledProgram) -> Option<CompiledProgram> {
    let (_, clr) = last_reads(p);
    for (c, r) in clr.iter().enumerate() {
        if let Some(r) = r {
            if *r >= 1 {
                let mut q = p.clone();
                q.plan.const_last_use[c] = Some(r - 1);
                return Some(q);
            }
        }
    }
    None
}

/// Free a still-read value right after its definition.
fn m_free_early(p: &CompiledProgram) -> Option<CompiledProgram> {
    let (lr, _) = last_reads(p);
    for i in 0..p.instrs.len() {
        if p.plan.is_output[i] || lr[i] <= i {
            continue;
        }
        let mut q = p.clone();
        for dead in q.plan.dies_after.iter_mut() {
            dead.retain(|&x| x != i);
        }
        q.plan.dies_after[i].push(i);
        return Some(q);
    }
    None
}

/// Free a requested output at the end of the program.
fn m_free_output(p: &CompiledProgram) -> Option<CompiledProgram> {
    let n = p.instrs.len();
    for r in &p.outputs {
        if let ValueRef::Out(i) = r {
            let mut q = p.clone();
            q.plan.dies_after[n - 1].push(*i);
            return Some(q);
        }
    }
    None
}

/// Point an instruction at its own (not-yet-defined) value.
fn m_dangling(p: &CompiledProgram) -> Option<CompiledProgram> {
    for j in 0..p.instrs.len() {
        if let CompiledInstr::Op { inputs, .. } = &p.instrs[j] {
            if inputs.is_empty() {
                continue;
            }
            let mut q = p.clone();
            if let CompiledInstr::Op { inputs, .. } = &mut q.instrs[j] {
                inputs[0] = ValueRef::Out(j);
            }
            rebuild(&mut q);
            return Some(q);
        }
    }
    None
}

/// Hand a fixed-arity op an extra (valid) operand.
fn m_extra_arity(p: &CompiledProgram) -> Option<CompiledProgram> {
    for j in 1..p.instrs.len() {
        let CompiledInstr::Op { op, .. } = &p.instrs[j] else { continue };
        if op.arity().is_none() {
            continue;
        }
        let mut q = p.clone();
        if let CompiledInstr::Op { inputs, .. } = &mut q.instrs[j] {
            inputs.push(ValueRef::Out(0));
        }
        rebuild(&mut q);
        return Some(q);
    }
    None
}

/// Structurally corrupt the plan (wrong vector length).
fn m_malformed_plan(p: &CompiledProgram) -> Option<CompiledProgram> {
    if p.plan.slot.is_empty() {
        return None;
    }
    let mut q = p.clone();
    q.plan.slot.pop();
    Some(q)
}

fn assert_killed(
    case: usize,
    seed: u64,
    class: &str,
    p: &CompiledProgram,
    spec: &SourceSpec,
    expect: DiagnosticKind,
) {
    match verify::verify_program(p, Some(spec), "mutant") {
        Ok(_) => panic!(
            "case {case} (seed {seed:#x}): `{class}` miscompile SURVIVED verification \
             (replay: GRAPH_VERIFY_SEED={seed:#x})"
        ),
        Err(diags) => assert!(
            diags.iter().any(|d| d.kind == expect),
            "case {case} (seed {seed:#x}): `{class}` was flagged, but never as {expect:?}: \
             {diags:?}"
        ),
    }
}

fn spec_for(program: &TraceProgram, outputs: &[ValueRef]) -> SourceSpec {
    let g = Graph::from_program(program, outputs).expect("generated program lifts");
    verify::source_spec(&g)
        .unwrap_or_else(|d| panic!("clean trace failed source verification: {d:?}"))
}

// ---------------------------------------------------------------------------
// the sweeps
// ---------------------------------------------------------------------------

/// Every mutation class, applied to every generated program where it is
/// applicable, must be flagged with the expected diagnostic kind — and
/// every class must have fired at least once across the sweep.
#[test]
fn seeded_miscompiles_are_all_killed() {
    std::env::set_var("FL_VERIFY", "1");
    let cases = env_usize("GRAPH_VERIFY_CASES", 120);
    // a pinned seed replays itself as case 0; the rest of the sweep
    // derives from it as usual
    let pinned: Option<u64> = std::env::var("GRAPH_VERIFY_SEED").ok().and_then(|v| {
        let v = v.trim();
        v.strip_prefix("0x")
            .map(|h| u64::from_str_radix(h, 16).ok())
            .unwrap_or_else(|| v.parse().ok())
    });
    let mut master = Rng::new(pinned.unwrap_or(0x5EED_F00D));
    let mut applied: BTreeMap<&'static str, usize> = BTreeMap::new();
    for case in 0..cases {
        let seed = match pinned {
            Some(s) if case == 0 => s,
            _ => master.next_u64(),
        };
        let mut rng = Rng::new(seed);
        let (program, outputs) = gen_program(&mut rng);
        let spec = spec_for(&program, &outputs);
        // fold off so the generator's skeleton survives into both
        // compiled forms; the clean sweep below covers fold
        let nofuse = CompileOptions { fold: false, fuse: false, ..Default::default() };
        let fused = CompileOptions { fold: false, ..Default::default() };
        let p_op = compile(&program, &outputs, &nofuse)
            .unwrap_or_else(|e| panic!("case {case} (seed {seed:#x}): compile(nofuse): {e}"));
        let p_fz = compile(&program, &outputs, &fused)
            .unwrap_or_else(|e| panic!("case {case} (seed {seed:#x}): compile(fuse): {e}"));
        let meta_op = verify::verify_program(&p_op, Some(&spec), "clean")
            .unwrap_or_else(|d| panic!("case {case} (seed {seed:#x}): clean nofuse: {d:?}"));
        let meta_fz = verify::verify_program(&p_fz, Some(&spec), "clean")
            .unwrap_or_else(|d| panic!("case {case} (seed {seed:#x}): clean fused: {d:?}"));

        use DiagnosticKind::*;
        let classes: Vec<(&'static str, Option<CompiledProgram>, DiagnosticKind)> = vec![
            ("rewire-broadcast", m_rewire_broadcast(&p_op, &meta_op), ShapeMismatch),
            ("drop-effect", m_drop_effect(&p_op), EffectMismatch),
            ("swap-effect-payload", m_swap_effect_payload(&p_op), EffectMismatch),
            ("fused-nonf32-input", m_fused_nonf32(&p_fz, &meta_fz), DTypeMismatch),
            ("fused-broken-broadcast", m_fused_broadcast(&p_fz, &meta_fz), FusionIllegal),
            ("alias-live-slot", m_alias_slot(&p_fz), MemPlanAlias),
            ("output-swap", m_output_swap(&p_op, &meta_op), OutputMismatch),
            ("donate-early", m_donate_early(&p_fz), DonationUnsafe),
            ("free-early", m_free_early(&p_fz), MemPlanUseAfterFree),
            ("free-output", m_free_output(&p_fz), OutputFreed),
            ("dangling-self-ref", m_dangling(&p_op), DanglingRef),
            ("extra-operand", m_extra_arity(&p_op), Arity),
            ("truncated-plan", m_malformed_plan(&p_fz), MemPlanMalformed),
        ];
        for (name, mutant, expect) in classes {
            if let Some(m) = mutant {
                assert_killed(case, seed, name, &m, &spec, expect);
                *applied.entry(name).or_insert(0) += 1;
            }
        }
    }
    // the skeleton makes every class applicable in every case; if one
    // never fired, the sweep silently lost coverage
    for name in [
        "rewire-broadcast",
        "drop-effect",
        "swap-effect-payload",
        "fused-nonf32-input",
        "fused-broken-broadcast",
        "alias-live-slot",
        "output-swap",
        "donate-early",
        "free-early",
        "free-output",
        "dangling-self-ref",
        "extra-operand",
        "truncated-plan",
    ] {
        assert!(
            applied.get(name).copied().unwrap_or(0) > 0,
            "mutation class `{name}` never applied — coverage lost ({applied:?})"
        );
    }
}

/// Clean programs compiled under every pass configuration verify with
/// zero diagnostics — and `FL_VERIFY=1` means `compile` itself already
/// re-verified after every pass.
#[test]
fn clean_programs_verify_with_zero_diagnostics() {
    std::env::set_var("FL_VERIFY", "1");
    let cases = env_usize("GRAPH_VERIFY_CASES", 120);
    let configs: Vec<(&str, CompileOptions)> = vec![
        ("full", CompileOptions::default()),
        ("none", CompileOptions::none()),
        ("dce", CompileOptions::only("dce")),
        ("fold", CompileOptions::only("fold")),
        ("cse", CompileOptions::only("cse")),
        ("fuse", CompileOptions::only("fuse")),
    ];
    let mut master = Rng::new(0x7E57_CA5E_5EED);
    for case in 0..cases {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let (program, outputs) = gen_program(&mut rng);
        let spec = spec_for(&program, &outputs);
        for (label, opts) in &configs {
            let p = compile(&program, &outputs, opts).unwrap_or_else(|e| {
                panic!("case {case} (seed {seed:#x}) config `{label}`: compile: {e}")
            });
            if let Err(d) = verify::verify_program(&p, Some(&spec), "clean") {
                panic!(
                    "case {case} (seed {seed:#x}) config `{label}`: FALSE POSITIVE \
                     ({} diagnostic(s)): {d:?}",
                    d.len()
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// hand-built minimal negatives: exact (kind, instr) per diagnostic
// ---------------------------------------------------------------------------

fn fh(data: &[f32], dims: &[usize]) -> Op {
    Op::FromHost { host: HostBuffer::F32(data.to_vec()), shape: Shape::new(dims.to_vec()) }
}

fn graph_of(instrs: Vec<(Op, Vec<ValueRef>)>, outputs: &[ValueRef]) -> Graph {
    Graph {
        consts: Vec::new(),
        nodes: instrs.into_iter().map(|(op, inputs)| Node { op, inputs }).collect(),
        outputs: outputs.to_vec(),
    }
}

#[test]
fn hand_built_graph_negatives_pin_kind_and_instr() {
    std::env::set_var("FL_VERIFY", "1");
    // self-reference: SSA violation at the exact node
    let g = graph_of(
        vec![(fh(&[1.0], &[1]), vec![]), (Op::Neg, vec![ValueRef::Out(1)])],
        &[ValueRef::Out(1)],
    );
    let d = verify::verify(&g, None, "t").unwrap_err();
    assert_eq!((d[0].kind, d[0].instr), (DiagnosticKind::DanglingRef, Some(1)), "{d:?}");

    // wrong operand count
    let g = graph_of(
        vec![(fh(&[1.0], &[1]), vec![]), (Op::Neg, vec![ValueRef::Out(0), ValueRef::Out(0)])],
        &[ValueRef::Out(1)],
    );
    let d = verify::verify(&g, None, "t").unwrap_err();
    assert_eq!((d[0].kind, d[0].instr), (DiagnosticKind::Arity, Some(1)), "{d:?}");

    // broadcast-incompatible binary
    let g = graph_of(
        vec![
            (fh(&[1.0, 2.0], &[2]), vec![]),
            (fh(&[1.0, 2.0, 3.0], &[3]), vec![]),
            (Op::Mul, vec![ValueRef::Out(0), ValueRef::Out(1)]),
        ],
        &[ValueRef::Out(2)],
    );
    let d = verify::verify(&g, None, "t").unwrap_err();
    assert_eq!((d[0].kind, d[0].instr), (DiagnosticKind::ShapeMismatch, Some(2)), "{d:?}");

    // effect payload divergence at the exact surviving instruction
    let rand = |lo: f64| Op::RandUniform {
        shape: Shape::new(vec![2]),
        lo,
        hi: lo + 1.0,
        dtype: DType::F32,
    };
    let src = graph_of(vec![(rand(0.0), vec![]), (rand(5.0), vec![])], &[ValueRef::Out(1)]);
    let spec = verify::source_spec(&src).unwrap();
    let swapped =
        graph_of(vec![(rand(5.0), vec![]), (rand(0.0), vec![])], &[ValueRef::Out(1)]);
    let d = verify::verify(&swapped, Some(&spec), "t").unwrap_err();
    assert_eq!((d[0].kind, d[0].instr), (DiagnosticKind::EffectMismatch, Some(0)), "{d:?}");

    // output dtype drifted from the source trace's promise
    let src = graph_of(vec![(fh(&[1.0, 2.0], &[2]), vec![])], &[ValueRef::Out(0)]);
    let spec = verify::source_spec(&src).unwrap();
    let drifted = graph_of(
        vec![
            (fh(&[1.0, 2.0], &[2]), vec![]),
            (Op::Astype { dtype: DType::I64 }, vec![ValueRef::Out(0)]),
        ],
        &[ValueRef::Out(1)],
    );
    let d = verify::verify(&drifted, Some(&spec), "t").unwrap_err();
    assert_eq!((d[0].kind, d[0].instr), (DiagnosticKind::OutputMismatch, None), "{d:?}");
}

#[test]
fn hand_built_program_negatives_pin_kind() {
    std::env::set_var("FL_VERIFY", "1");
    // a: fh [2,3]; b: fh [2,3]; fused { (a + const) + b, neg } — one
    // kernel over two traced values and one constant
    let program = TraceProgram {
        consts: vec![Tensor::full(vec![2, 3], 1.0, DType::F32)],
        instrs: vec![
            TraceInstr { op: fh(&[1.0; 6], &[2, 3]), inputs: vec![] },
            TraceInstr { op: fh(&[2.0; 6], &[2, 3]), inputs: vec![] },
            TraceInstr { op: Op::Add, inputs: vec![ValueRef::Out(0), ValueRef::Const(0)] },
            TraceInstr { op: Op::Add, inputs: vec![ValueRef::Out(2), ValueRef::Out(1)] },
            TraceInstr { op: Op::Neg, inputs: vec![ValueRef::Out(3)] },
        ],
    };
    let outputs = vec![ValueRef::Out(4)];
    let spec = spec_for(&program, &outputs);
    let opts = CompileOptions { fold: false, ..Default::default() };
    let p = compile(&program, &outputs, &opts).unwrap();
    verify::verify_program(&p, Some(&spec), "clean").expect("base program is clean");
    let j = p
        .instrs
        .iter()
        .position(|i| matches!(i, CompiledInstr::Fused(_)))
        .expect("the element-wise chain fused into a kernel");
    let kernel_value_input = {
        let CompiledInstr::Fused(k) = &p.instrs[j] else { unreachable!() };
        *k.inputs
            .iter()
            .find_map(|r| match r {
                ValueRef::Out(i) => Some(i),
                ValueRef::Const(_) => None,
            })
            .expect("kernel reads a traced value")
    };
    let n = p.instrs.len();

    // forward step reference inside the kernel
    let mut q = p.clone();
    if let CompiledInstr::Fused(k) = &mut q.instrs[j] {
        k.steps[1].args[0] = FusedArg::Step(usize::MAX);
    }
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(
        d.iter().any(|x| x.kind == DiagnosticKind::FusionIllegal && x.instr == Some(j)),
        "{d:?}"
    );

    // a kernel input that is no longer f32
    let mut q = p.clone();
    q.instrs[kernel_value_input] = CompiledInstr::Op {
        op: Op::Full { shape: Shape::new(vec![2, 3]), value: 0.0, dtype: DType::I64 },
        inputs: vec![],
    };
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(
        d.iter().any(|x| x.kind == DiagnosticKind::DTypeMismatch && x.instr == Some(j)),
        "{d:?}"
    );

    // the kernel output takes the slot of a value it still reads
    let mut q = p.clone();
    q.plan.slot[j] = q.plan.slot[kernel_value_input];
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(
        d.iter().any(|x| x.kind == DiagnosticKind::MemPlanAlias && x.instr == Some(j)),
        "{d:?}"
    );

    // a kernel input freed before the kernel runs
    let mut q = p.clone();
    for dead in q.plan.dies_after.iter_mut() {
        dead.retain(|&x| x != kernel_value_input);
    }
    q.plan.dies_after[kernel_value_input].push(kernel_value_input);
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(
        d.iter().any(|x| {
            x.kind == DiagnosticKind::MemPlanUseAfterFree && x.instr == Some(kernel_value_input)
        }),
        "{d:?}"
    );

    // the requested output freed at the end of the program
    let mut q = p.clone();
    q.plan.dies_after[n - 1].push(j);
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(
        d.iter().any(|x| x.kind == DiagnosticKind::OutputFreed && x.instr == Some(j)),
        "{d:?}"
    );

    // the constant donated before the kernel reads it
    let mut q = p.clone();
    q.plan.const_last_use[0] = Some(j - 1);
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(d.iter().any(|x| x.kind == DiagnosticKind::DonationUnsafe), "{d:?}");

    // a free list pointing at a value that does not exist
    let mut q = p.clone();
    q.plan.dies_after[0].push(usize::MAX);
    let d = verify::verify_program(&q, Some(&spec), "t").unwrap_err();
    assert!(d.iter().any(|x| x.kind == DiagnosticKind::MemPlanMalformed), "{d:?}");
}
