//! Schedule-fuzzing parity harness for the continuous batcher.
//!
//! Each case builds a random small LM, a random paged-KV/scheduler
//! configuration (page size, slot count, a pool deliberately sized down
//! to the backpressure regime, random decode-compilation buckets —
//! auto, disabled, or a deliberately narrow set that forces eager
//! fallbacks — and a random Sarathi prefill chunk size), and a random
//! request mix (prompt lengths including long prompts that split into
//! many chunks, generation budgets including zero, greedy and top-k
//! sampling, distinct sampling seeds), then serves the mix through
//! [`ContinuousBatcher`] under a randomized arrival pattern. Every
//! request's report must be **bit-identical** — token stream *and* the
//! `[V]` logits each sampling step saw — to a solo [`generate()`] call
//! with the same prompt and options, whatever the iteration batches
//! looked like and whether each iteration ran compiled or eager.
//! Afterwards the pool must be fully drained (no leaked pages) and the
//! compile/chunk telemetry must balance.
//!
//! Knobs (see docs/ARCHITECTURE.md, "Testing & fuzzing guide"):
//!
//! - `SERVE_FUZZ_CASES`: schedules to fuzz (default 25; CI's `fuzz` job
//!   raises this to 200+).
//! - `SERVE_FUZZ_SEED` (decimal or 0x-hex): pins case 0's generation
//!   seed (later cases derive from it). Every failure panic prints the
//!   *case* seed; re-running with that value as `SERVE_FUZZ_SEED` and
//!   `SERVE_FUZZ_CASES=1` replays exactly the failing schedule.

use std::sync::Arc;

use flashlight::models::BertLike;
use flashlight::serve::{generate, ContinuousBatcher, ContinuousConfig, GenerateOptions, Sampling};
use flashlight::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// `SERVE_FUZZ_SEED`, if set (decimal or 0x-hex). A pinned seed is used
/// *directly* as case 0's generation seed, so the seed printed by a
/// failure panic replays that exact schedule as case 0.
fn env_seed() -> Option<u64> {
    match std::env::var("SERVE_FUZZ_SEED") {
        Ok(v) => {
            let v = v.trim();
            let parsed = match v.strip_prefix("0x") {
                Some(hex) => u64::from_str_radix(hex, 16).ok(),
                None => v.parse().ok(),
            };
            Some(parsed.unwrap_or_else(|| panic!("unparseable SERVE_FUZZ_SEED: {v}")))
        }
        Err(_) => None,
    }
}

/// One randomly drawn generation request.
#[derive(Debug, Clone)]
struct Req {
    prompt: Vec<i64>,
    opts: GenerateOptions,
}

fn gen_request(rng: &mut Rng, vocab: usize, max_len: usize, i: usize) -> Req {
    // mostly short prompts, but 1-in-4 long (up to max_len - 9, leaving
    // decode budget): long admissions are what chunked prefill splits
    let prompt_len = if rng.below(4) == 0 {
        1 + rng.below(max_len - 9)
    } else {
        1 + rng.below(10)
    };
    let budget = max_len - prompt_len;
    // 0..=8 new tokens, zero included: a no-decode request must still be
    // answered (with its prompt unchanged) without touching the pool
    let max_new = rng.below(9.min(budget + 1));
    let sampling = if rng.below(2) == 0 {
        Sampling::Greedy
    } else {
        Sampling::TopK { k: 1 + rng.below(8), temperature: 0.5 + 0.25 * rng.below(5) as f64 }
    };
    Req {
        prompt: (0..prompt_len).map(|_| rng.below(vocab) as i64).collect(),
        opts: GenerateOptions {
            max_new_tokens: max_new,
            sampling,
            // distinct per-request streams: request i must get stream i's
            // draws no matter which iteration batches it rode in
            seed: rng.next_u64() ^ i as u64,
            use_cache: true,
            record_logits: true,
        },
    }
}

fn run_fuzz(cases: usize, master_seed: u64, pinned: bool) {
    // every compile in the sweep (prefill buckets, decode step, solo
    // replays) re-runs the static verifier after each optimization pass:
    // a serving-path miscompile surfaces as a typed diagnostic here, not
    // as a parity mismatch three layers later
    std::env::set_var("FL_VERIFY", "1");
    let mut master = Rng::new(master_seed);
    for case in 0..cases {
        // a pinned (SERVE_FUZZ_SEED) value replays itself as case 0; the
        // rest of the sweep derives from it as usual
        let case_seed = if pinned && case == 0 { master_seed } else { master.next_u64() };
        let mut rng = Rng::new(case_seed);

        // random model geometry; weights pinned to the case seed
        flashlight::util::rng::seed(case_seed ^ 0xF1A5_811F);
        let vocab = 16 + rng.below(33);
        let heads = [1, 2, 4][rng.below(3)];
        let dim = heads * [4, 8][rng.below(2)];
        let depth = 1 + rng.below(2);
        let max_len = 20 + rng.below(12);
        let model = Arc::new(BertLike::new(vocab, dim, heads, depth, max_len));

        // random request mix
        let n_requests = 2 + rng.below(6);
        let requests: Vec<Req> =
            (0..n_requests).map(|i| gen_request(&mut rng, vocab, max_len, i)).collect();

        // random scheduler/pool shape. The pool is drawn between "exactly
        // the largest single reservation" and "everyone at once", so many
        // cases run in the backpressure regime where admission stalls.
        let page_tokens = 1 + rng.below(8);
        let max_active = 1 + rng.below(4);
        let per_req: Vec<usize> = requests
            .iter()
            .map(|r| (r.prompt.len() + r.opts.max_new_tokens).div_ceil(page_tokens))
            .collect();
        let lo = per_req.iter().copied().max().unwrap_or(1).max(1);
        let hi = per_req.iter().sum::<usize>().max(lo);
        let pool_pages = lo + rng.below(hi - lo + 1);
        // decode-compilation buckets: auto (every batch size fits),
        // disabled (all-eager), or one deliberately narrow bucket that
        // forces a random mix of compiled iterations and eager fallbacks
        let decode_buckets = match rng.below(3) {
            0 => None,
            1 => Some(Vec::new()),
            _ => Some(vec![1 + rng.below(max_active)]),
        };
        let prefill_chunk = if rng.below(3) == 0 { None } else { Some(1 + rng.below(6)) };
        let cfg = ContinuousConfig {
            max_active,
            page_tokens,
            pool_pages: Some(pool_pages),
            decode_buckets: decode_buckets.clone(),
            prefill_chunk,
        };

        let ctx = |stage: &str, detail: String| {
            format!(
                "serve_continuous_fuzz case {case} (seed {case_seed:#x}): {stage}: {detail}\n\
                 model: vocab={vocab} dim={dim} heads={heads} depth={depth} max_len={max_len}\n\
                 cfg: page_tokens={page_tokens} max_active={max_active} pool_pages={pool_pages} \
                 decode_buckets={decode_buckets:?} prefill_chunk={prefill_chunk:?}\n\
                 requests: {requests:?}\n\
                 reproduce with SERVE_FUZZ_SEED={case_seed:#x} SERVE_FUZZ_CASES=1"
            )
        };

        let batcher = ContinuousBatcher::start(Arc::clone(&model), &cfg)
            .unwrap_or_else(|e| panic!("{}", ctx("start", e.to_string())));

        // randomized arrival pattern: either everything up front, or in
        // two waves with the second joining while the first is mid-decode
        let wave_split =
            if rng.below(2) == 0 { requests.len() } else { 1 + rng.below(requests.len()) };
        let mut handles = Vec::with_capacity(requests.len());
        for r in &requests[..wave_split] {
            handles.push(batcher.submit(&r.prompt, &r.opts));
        }
        if wave_split < requests.len() {
            // wait for one in-flight report before the second wave so the
            // late arrivals genuinely join a drained-down batch
            let first = handles.remove(0);
            let served =
                first.wait().unwrap_or_else(|e| panic!("{}", ctx("wave 1", e.to_string())));
            check_parity(&model, &requests[0], &served, 0, &ctx);
            for r in &requests[wave_split..] {
                handles.push(batcher.submit(&r.prompt, &r.opts));
            }
            // handles[..] now corresponds to requests[1..]
            for (k, handle) in handles.into_iter().enumerate() {
                let served =
                    handle.wait().unwrap_or_else(|e| panic!("{}", ctx("wait", e.to_string())));
                check_parity(&model, &requests[k + 1], &served, k + 1, &ctx);
            }
        } else {
            for (k, handle) in handles.into_iter().enumerate() {
                let served =
                    handle.wait().unwrap_or_else(|e| panic!("{}", ctx("wait", e.to_string())));
                check_parity(&model, &requests[k], &served, k, &ctx);
            }
        }

        let stats = batcher.stats();
        assert!(
            stats.completed == requests.len() as u64,
            "{}",
            ctx("stats", format!("completed {} of {}", stats.completed, requests.len()))
        );
        assert!(
            stats.pool.leased_pages == 0,
            "{}",
            ctx("pool drain", format!("{} pages still leased", stats.pool.leased_pages))
        );
        assert!(
            stats.pool.total_leases == stats.pool.total_releases,
            "{}",
            ctx(
                "pool ledger",
                format!(
                    "{} leases vs {} releases",
                    stats.pool.total_leases,
                    stats.pool.total_releases
                )
            )
        );
        // compile telemetry must balance: every iteration was exactly one
        // of compiled / eager-fallback, and the auto bucket set (None)
        // covers every feasible batch size so it can never miss
        assert!(
            stats.compiled_iterations + stats.compile_misses == stats.iterations,
            "{}",
            ctx(
                "compile ledger",
                format!(
                    "{} compiled + {} misses != {} iterations",
                    stats.compiled_iterations, stats.compile_misses, stats.iterations
                )
            )
        );
        if decode_buckets.is_none() {
            assert!(
                stats.compile_misses == 0,
                "{}",
                ctx("auto buckets", format!("{} compile misses", stats.compile_misses))
            );
        }
        // chunk accounting: at least one prefill pass per admission, and
        // with chunking off the two counters coincide
        assert!(
            stats.prefill_chunks >= stats.prefills,
            "{}",
            ctx(
                "chunk ledger",
                format!("{} chunks < {} prefills", stats.prefill_chunks, stats.prefills)
            )
        );
        if prefill_chunk.is_none() {
            assert!(
                stats.prefill_chunks == stats.prefills && stats.chunked_admissions == 0,
                "{}",
                ctx(
                    "unchunked prefill",
                    format!(
                        "{} chunks / {} prefills / {} chunked admissions",
                        stats.prefill_chunks, stats.prefills, stats.chunked_admissions
                    )
                )
            );
        }
        batcher.shutdown();
    }
    println!(
        "serve_continuous_fuzz: {cases} schedules bit-identical (master seed {master_seed:#x})"
    );
}

/// The parity oracle: a continuous-batched report must match a solo
/// [`generate()`] call bit-for-bit — tokens and every step's logits.
fn check_parity(
    model: &BertLike,
    req: &Req,
    served: &flashlight::serve::GenerateReport,
    k: usize,
    ctx: &dyn Fn(&str, String) -> String,
) {
    let solo = generate(model, &req.prompt, &req.opts)
        .unwrap_or_else(|e| panic!("{}", ctx("solo reference", e.to_string())));
    assert!(
        served.tokens == solo.tokens,
        "{}",
        ctx(
            "token parity",
            format!("request {k}: served {:?}, solo {:?}", served.tokens, solo.tokens)
        )
    );
    assert!(
        served.generated == solo.generated,
        "{}",
        ctx("generated count", format!("request {k}: {} vs {}", served.generated, solo.generated))
    );
    assert!(
        served.step_logits.len() == solo.step_logits.len(),
        "{}",
        ctx(
            "step count",
            format!("request {k}: {} vs {}", served.step_logits.len(), solo.step_logits.len())
        )
    );
    for (step, (a, b)) in served.step_logits.iter().zip(&solo.step_logits).enumerate() {
        let same = a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(
            same,
            "{}",
            ctx("logit parity", format!("request {k} step {step}: served {a:?}, solo {b:?}"))
        );
    }
    // telemetry-balance oracle: whenever the request carried an obs
    // timeline (FL_TRACE=1 / the tracing fuzz test below), it must hold
    // exactly one "sample" event per generated token and end in "retire"
    if let Some(tl) = &served.timeline {
        let samples = tl.events.iter().filter(|e| e.what == "sample").count();
        assert!(
            samples == served.generated,
            "{}",
            ctx(
                "timeline ledger",
                format!("request {k}: {samples} sample events vs {} generated", served.generated)
            )
        );
        assert!(
            tl.events.last().map(|e| e.what) == Some("retire"),
            "{}",
            ctx("timeline retire", format!("request {k}: last event {:?}", tl.events.last()))
        );
    }
}

/// The headline run: randomized schedules, every report bit-identical to
/// solo decode, pool drained afterwards.
#[test]
fn continuous_schedules_are_bit_identical_to_solo_decode() {
    let cases = env_usize("SERVE_FUZZ_CASES", 25);
    let pinned = env_seed();
    run_fuzz(cases, pinned.unwrap_or(0x0DCA_11ED), pinned.is_some());
}

/// Tracing mode: with the obs layer recording (as under `FL_TRACE=1`),
/// every schedule must stay bit-identical to solo decode — observation
/// may never perturb the bits — and every report now carries a timeline
/// whose `"sample"` events balance the generated-token count
/// (`check_parity` asserts the ledger whenever a timeline is present).
#[test]
fn tracing_preserves_parity_and_balances_timelines() {
    let was = flashlight::obs::enabled();
    flashlight::obs::set_enabled(true);
    run_fuzz(env_usize("SERVE_FUZZ_TRACE_CASES", 5), 0x7AC3_11ED, false);
    flashlight::obs::set_enabled(was);
}
