//! Pass-level tests for the graph compiler: each optimization is pinned
//! down individually with snapshot-style assertions on the compiled
//! instruction stream, and the memory plan is validated both structurally
//! (no aliasing of live values) and under instrumented execution.

use flashlight::memory::telemetry::replay;
use flashlight::memory::DefaultMemoryManager;
use flashlight::tensor::cpu::CpuBackend;
use flashlight::tensor::graph::{compile, CompileOptions, CompiledInstr};
use flashlight::tensor::trace::{TraceInstr, TraceProgram, ValueRef};
use flashlight::tensor::{DType, HostBuffer, Op, Shape};

fn fh(data: &[f32], shape: &[usize]) -> Op {
    Op::FromHost { host: HostBuffer::F32(data.to_vec()), shape: Shape::new(shape.to_vec()) }
}

fn prog(instrs: Vec<(Op, Vec<ValueRef>)>) -> TraceProgram {
    TraceProgram {
        consts: Vec::new(),
        instrs: instrs.into_iter().map(|(op, inputs)| TraceInstr { op, inputs }).collect(),
    }
}

fn o(i: usize) -> ValueRef {
    ValueRef::Out(i)
}

/// Optimized outputs must equal the reference replay (plain equality is
/// enough here; the fuzzer covers bit-identity at scale).
fn assert_matches_reference(p: &TraceProgram, outputs: &[ValueRef], opts: &CompileOptions) {
    let cpu = CpuBackend::shared();
    let reference = p.replay_on(cpu.as_ref()).unwrap();
    let compiled = compile(p, outputs, opts).unwrap();
    let got = compiled.run(cpu.as_ref()).unwrap();
    for (k, r) in outputs.iter().enumerate() {
        let want = match r {
            ValueRef::Out(i) => &reference[*i],
            ValueRef::Const(i) => &p.consts[*i],
        };
        assert_eq!(got[k].dims(), want.dims(), "output {k} shape");
        assert_eq!(got[k].to_vec(), want.to_vec(), "output {k} value");
    }
}

// ---- dead-code elimination --------------------------------------------

#[test]
fn dce_removes_exactly_the_dead_ops() {
    let p = prog(vec![
        (fh(&[1.0, 2.0], &[2]), vec![]),          // 0: live (feeds 2 and 4)
        (fh(&[3.0, 4.0], &[2]), vec![]),          // 1: live (feeds 2)
        (Op::Add, vec![o(0), o(1)]),              // 2: live (feeds 4)
        (Op::Mul, vec![o(2), o(2)]),              // 3: dead
        (Op::Tanh, vec![o(2)]),                   // 4: output
        (Op::Neg, vec![o(3)]),                    // 5: dead (only feeds off dead 3)
    ]);
    let compiled = compile(&p, &[o(4)], &CompileOptions::only("dce")).unwrap();
    assert_eq!(compiled.op_names(), vec!["from_host", "from_host", "add", "tanh"]);
    assert_eq!(compiled.report.changed_by("dce"), 2);
    assert_matches_reference(&p, &[o(4)], &CompileOptions::only("dce"));
}

#[test]
fn dce_keeps_effectful_ops_and_their_operands() {
    let p = prog(vec![
        (fh(&[1.0], &[1]), vec![]), // 0: only feeds the dead call_ext
        (
            Op::RandUniform {
                shape: Shape::new(vec![2]),
                lo: 0.0,
                hi: 1.0,
                dtype: DType::F32,
            },
            vec![],
        ), // 1: dead but effectful (advances the RNG stream)
        (Op::CallExt { name: "nonexistent".into() }, vec![o(0)]), // 2: dead but effectful
        (fh(&[5.0], &[1]), vec![]), // 3: output
    ]);
    let g_opts = CompileOptions::only("dce");
    let compiled = compile(&p, &[o(3)], &g_opts).unwrap();
    // rand_uniform, call_ext, and call_ext's operand all survive
    assert_eq!(
        compiled.op_names(),
        vec!["from_host", "rand_uniform", "call_ext", "from_host"]
    );
}

// ---- constant folding --------------------------------------------------

#[test]
fn fold_leaves_no_all_constant_ops() {
    let p = prog(vec![
        (fh(&[4.0, 9.0], &[2]), vec![]),  // 0
        (Op::Sqrt, vec![o(0)]),           // 1: foldable
        (Op::Neg, vec![o(1)]),            // 2: foldable (cascade)
        (
            Op::RandUniform {
                shape: Shape::new(vec![2]),
                lo: 0.0,
                hi: 1.0,
                dtype: DType::F32,
            },
            vec![],
        ), // 3: never folded
        (Op::Add, vec![o(2), o(3)]),      // 4: operand 3 is runtime -> not folded
    ]);
    let opts =
        CompileOptions { dce: false, fold: true, cse: false, fuse: false, ..Default::default() };
    let compiled = compile(&p, &[o(4)], &opts).unwrap();
    // everything deterministic-and-constant folded away; no remaining
    // instruction has all-constant inputs
    assert_eq!(compiled.op_names(), vec!["rand_uniform", "add"]);
    for instr in &compiled.instrs {
        if let CompiledInstr::Op { op, inputs } = instr {
            let all_const = !inputs.is_empty()
                && inputs.iter().all(|r| matches!(r, ValueRef::Const(_)));
            assert!(
                !all_const || matches!(op, Op::CallExt { .. }),
                "unfolded all-constant op {}",
                op.name()
            );
        }
    }
    assert_eq!(compiled.report.changed_by("fold"), 3); // 0, 1, 2
}

#[test]
fn fold_respects_the_size_cap() {
    let p = prog(vec![(
        Op::Full { shape: Shape::new(vec![1024]), value: 3.0, dtype: DType::F32 },
        vec![],
    )]);
    let small_cap = CompileOptions {
        dce: false,
        cse: false,
        fuse: false,
        fold_numel_cap: 16,
        ..Default::default()
    };
    let compiled = compile(&p, &[o(0)], &small_cap).unwrap();
    assert_eq!(compiled.op_names(), vec!["full"], "oversized fold must be skipped");
    assert_matches_reference(&p, &[o(0)], &small_cap);
}

// ---- common-subexpression elimination ----------------------------------

#[test]
fn cse_merges_syntactically_equal_nodes() {
    let p = prog(vec![
        (fh(&[1.0, 2.0], &[2]), vec![]), // 0
        (fh(&[5.0, 6.0], &[2]), vec![]), // 1
        (Op::Add, vec![o(0), o(1)]),     // 2
        (Op::Add, vec![o(0), o(1)]),     // 3: duplicate of 2
        (Op::Tanh, vec![o(2)]),          // 4
        (Op::Tanh, vec![o(3)]),          // 5: duplicate once 3 merges into 2
        (Op::Mul, vec![o(4), o(5)]),     // 6
    ]);
    let opts = CompileOptions { fold: false, fuse: false, ..Default::default() }; // cse + dce
    let compiled = compile(&p, &[o(6)], &opts).unwrap();
    assert_eq!(
        compiled.op_names(),
        vec!["from_host", "from_host", "add", "tanh", "mul"]
    );
    assert_eq!(compiled.report.changed_by("cse"), 2);
    assert_matches_reference(&p, &[o(6)], &opts);
}

#[test]
fn cse_never_merges_random_ops() {
    let rand = Op::RandUniform {
        shape: Shape::new(vec![3]),
        lo: 0.0,
        hi: 1.0,
        dtype: DType::F32,
    };
    let p = prog(vec![
        (rand.clone(), vec![]),      // 0
        (rand, vec![]),              // 1: syntactically equal, distinct draws
        (Op::Sub, vec![o(0), o(1)]), // 2
    ]);
    let opts = CompileOptions { fold: false, fuse: false, ..Default::default() };
    let compiled = compile(&p, &[o(2)], &opts).unwrap();
    assert_eq!(compiled.op_names(), vec!["rand_uniform", "rand_uniform", "sub"]);
}

// ---- element-wise fusion ------------------------------------------------

#[test]
fn fusion_collapses_a_chain_into_one_kernel() {
    let p = prog(vec![
        (fh(&[1.0, -2.0, 3.0, -4.0], &[4]), vec![]), // 0
        (fh(&[0.5, 0.5, 0.5, 0.5], &[4]), vec![]),   // 1
        (Op::Add, vec![o(0), o(1)]),                 // 2
        (Op::Tanh, vec![o(2)]),                      // 3
        (Op::Abs, vec![o(3)]),                       // 4
        (Op::Sqrt, vec![o(4)]),                      // 5
    ]);
    let opts = CompileOptions::only("fuse");
    let compiled = compile(&p, &[o(5)], &opts).unwrap();
    assert_eq!(compiled.op_names(), vec!["from_host", "from_host", "fused"]);
    let CompiledInstr::Fused(k) = &compiled.instrs[2] else {
        panic!("expected a fused kernel")
    };
    assert_eq!(k.steps.len(), 4);
    assert_matches_reference(&p, &[o(5)], &opts);
}

#[test]
fn fusion_never_crosses_a_non_elementwise_boundary() {
    let p = prog(vec![
        (fh(&[1.0, 2.0, 3.0, 4.0], &[2, 2]), vec![]), // 0
        (Op::Neg, vec![o(0)]),                        // 1: single ew node -> stays plain
        (Op::Matmul, vec![o(1), o(1)]),               // 2: boundary
        (Op::Tanh, vec![o(2)]),                       // 3 ┐ fuse
        (Op::Exp, vec![o(3)]),                        // 4 ┘
        (Op::Sum { axes: vec![0, 1], keepdims: false }, vec![o(4)]), // 5: boundary
    ]);
    let opts = CompileOptions::only("fuse");
    let compiled = compile(&p, &[o(5)], &opts).unwrap();
    assert_eq!(
        compiled.op_names(),
        vec!["from_host", "neg", "matmul", "fused", "sum"]
    );
    let CompiledInstr::Fused(k) = &compiled.instrs[3] else {
        panic!("expected a fused kernel")
    };
    assert!(k.steps.iter().all(|s| matches!(s.op, Op::Tanh | Op::Exp)));
    assert_matches_reference(&p, &[o(5)], &opts);
}

#[test]
fn fusion_shares_diamond_subgraphs_inside_one_kernel() {
    // e = exp(x); out = (e + c) * (e - c): the old lazy tree walk would
    // duplicate e; the kernel must contain it exactly once
    let p = prog(vec![
        (fh(&[0.1, 0.2, 0.3], &[3]), vec![]), // 0
        (fh(&[1.0, 1.0, 1.0], &[3]), vec![]), // 1
        (Op::Exp, vec![o(0)]),                // 2: shared
        (Op::Add, vec![o(2), o(1)]),          // 3
        (Op::Sub, vec![o(2), o(1)]),          // 4
        (Op::Mul, vec![o(3), o(4)]),          // 5
    ]);
    let opts = CompileOptions::only("fuse");
    let compiled = compile(&p, &[o(5)], &opts).unwrap();
    assert_eq!(compiled.op_names(), vec!["from_host", "from_host", "fused"]);
    let CompiledInstr::Fused(k) = &compiled.instrs[2] else {
        panic!("expected a fused kernel")
    };
    let exps = k.steps.iter().filter(|s| matches!(s.op, Op::Exp)).count();
    assert_eq!(exps, 1, "shared subgraph must be a single step");
    assert_eq!(k.steps.len(), 4);
    assert_matches_reference(&p, &[o(5)], &opts);
}

#[test]
fn fusion_materializes_values_shared_across_regions() {
    // e feeds a fused region AND a reduction: it must materialize once as
    // its own value, not be duplicated into the kernel
    let p = prog(vec![
        (fh(&[0.5, 1.5], &[2]), vec![]),                     // 0
        (Op::Exp, vec![o(0)]),                               // 1: shared across a boundary
        (Op::Sum { axes: vec![0], keepdims: true }, vec![o(1)]), // 2: non-ew consumer
        (Op::Add, vec![o(1), o(2)]),                         // 3 ┐ fuse candidates
        (Op::Tanh, vec![o(3)]),                              // 4 ┘
    ]);
    let opts = CompileOptions::only("fuse");
    let compiled = compile(&p, &[o(4)], &opts).unwrap();
    assert_eq!(compiled.op_names(), vec!["from_host", "exp", "sum", "fused"]);
    assert_matches_reference(&p, &[o(4)], &opts);
}

#[test]
fn fusion_skips_non_f32_chains() {
    let p = prog(vec![
        (Op::Arange { n: 6, dtype: DType::I64 }, vec![]), // 0
        (Op::Neg, vec![o(0)]),                            // 1: i64 -> no fusion
        (Op::Abs, vec![o(1)]),                            // 2
    ]);
    let opts = CompileOptions::only("fuse");
    let compiled = compile(&p, &[o(2)], &opts).unwrap();
    assert_eq!(compiled.op_names(), vec!["arange", "neg", "abs"]);
    assert_matches_reference(&p, &[o(2)], &opts);
}

// ---- memory plan ---------------------------------------------------------

/// A chain program long enough for slot reuse to matter.
fn chain_program() -> TraceProgram {
    prog(vec![
        (fh(&[1.0, 2.0, 3.0, 4.0], &[4]), vec![]),
        (Op::Neg, vec![o(0)]),
        (Op::Abs, vec![o(1)]),
        (Op::Exp, vec![o(2)]),
        (Op::Log, vec![o(3)]),
        (Op::Tanh, vec![o(4)]),
        (Op::Sqrt, vec![o(5)]),
    ])
}

#[test]
fn memory_plan_never_aliases_live_values() {
    // structural check on a plan with real reuse (fusion off so the chain
    // stays long), plus instrumented execution: outputs must survive the
    // frees and match the reference
    let p = chain_program();
    let opts = CompileOptions::none();
    let compiled = compile(&p, &[o(3), o(6)], &opts).unwrap();
    compiled.plan.check_no_aliasing().unwrap();
    assert!(compiled.plan.num_slots < compiled.len(), "chain must reuse slots");
    // o(3) is read by instr 4 but is also an output: it must stay pinned
    assert!(compiled.plan.is_output[3]);
    assert_matches_reference(&p, &[o(3), o(6)], &opts);
}

#[test]
fn executor_reports_planned_vs_naive_peaks() {
    let p = chain_program();
    let opts = CompileOptions::none();
    let compiled = compile(&p, &[o(6)], &opts).unwrap();
    let cpu = CpuBackend::shared();
    let (outs, stats) = compiled.run_detailed(cpu.as_ref(), &[]).unwrap();
    assert_eq!(outs.len(), 1);
    // 7 instrs x 16 bytes each: naive keeps all alive, the plan keeps at
    // most two values (producer + consumer) plus nothing pinned early
    assert_eq!(stats.naive_peak_bytes, 7 * 16);
    assert!(
        stats.planned_peak_bytes <= 2 * 16,
        "planned peak {} exceeds two live chain values",
        stats.planned_peak_bytes
    );
    assert!(stats.buffer_slots < stats.executed_instrs);
}

#[test]
fn exec_alloc_events_replay_through_memory_telemetry() {
    let p = chain_program();
    let compiled = compile(&p, &[o(6)], &CompileOptions::none()).unwrap();
    let cpu = CpuBackend::shared();
    let (_, stats) = compiled.run_detailed(cpu.as_ref(), &[]).unwrap();
    // the event stream is a well-formed alloc/free trace: replaying it
    // against a fresh manager frees everything except the pinned output
    // (replay() releases still-live ids at the end itself)
    let mgr = DefaultMemoryManager::new();
    let (mstats, _frag) = replay(&stats.events, &mgr);
    assert_eq!(mstats.allocated_bytes, 0, "replay must balance allocs and frees");
    assert_eq!(mstats.alloc_count, 7);
    // at most two chain values live at once: two 64-byte-aligned blocks
    assert!(mstats.peak_allocated_bytes <= 2 * 64, "peak {}", mstats.peak_allocated_bytes);
}

// ---- pipeline composition ------------------------------------------------

#[test]
fn full_pipeline_reports_every_pass() {
    let p = prog(vec![
        (fh(&[1.0, 2.0], &[2]), vec![]),  // 0
        (Op::Sqrt, vec![o(0)]),           // 1: folds
        (Op::Neg, vec![o(1)]),            // 2: folds
        (Op::Neg, vec![o(1)]),            // 3: folds
        (Op::Mul, vec![o(2), o(3)]),      // 4: folds
        (Op::Tanh, vec![o(4)]),           // 5: folds
    ]);
    let compiled = compile(&p, &[o(5)], &CompileOptions::default()).unwrap();
    assert!(compiled.is_empty(), "all-constant program must fold away: {:?}", compiled.op_names());
    let ran: Vec<&str> = compiled.report.passes.iter().map(|r| r.pass).collect();
    for pass in ["dce", "fold", "cse", "fuse"] {
        assert!(ran.contains(&pass), "pass {pass} missing from report: {ran:?}");
    }
    assert_matches_reference(&p, &[o(5)], &CompileOptions::default());
}
