//! End-to-end validation driver (DESIGN.md E2E mandate): train a
//! transformer language model for a few hundred steps on a tiny synthetic
//! corpus with bigram structure, logging the loss curve; then sample from
//! the model to show it learned the structure. All layers compose: data
//! pipeline -> model zoo -> autograd -> optimizer -> trainer -> meters.
//!
//! Run: `cargo run --release --example train_transformer [steps]`
//! Results are recorded in EXPERIMENTS.md.

use std::sync::Arc;

use flashlight::coordinator::{train_lm, TrainConfig};
use flashlight::models::BertLike;
use flashlight::nn::num_params;
use flashlight::data::Dataset;
use flashlight::pkg::text::AutoregressiveLmDataset;
use flashlight::util::rng::Rng;

const VOCAB: usize = 256;
const SEQ: usize = 32;

/// Corpus with strong deterministic bigram structure: 85% of transitions
/// follow `next = (prev * 7 + 3) % VOCAB`, the rest are uniform noise.
/// Cross-entropy of the true process ≈ 0.15·ln(V) + H(0.15) ≈ 1.3 nats.
fn corpus(len: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let mut toks = vec![1usize];
    for _ in 0..len {
        let prev = *toks.last().unwrap();
        let next =
            if rng.uniform() < 0.85 { (prev * 7 + 3) % VOCAB } else { rng.below(VOCAB) };
        toks.push(next);
    }
    toks
}

fn main() {
    let steps: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    flashlight::util::rng::seed(7);

    let train_ds = Arc::new(AutoregressiveLmDataset::new(corpus(30_000, 1), SEQ, 7));
    let model = BertLike::new(VOCAB, 128, 4, 2, SEQ + 1);
    println!(
        "model: {} — {} parameters, {} train windows",
        flashlight::nn::Module::name(&model),
        num_params(&model),
        train_ds.len()
    );

    let cfg = TrainConfig {
        model: "bert".into(),
        optimizer: "adam".into(),
        lr: 1e-3,
        steps,
        batch_size: 16,
        grad_clip: 1.0,
        seed: 7,
        log_every: 20,
        ..Default::default()
    };

    let uniform = (VOCAB as f64).ln();
    println!("uniform baseline loss: {uniform:.3} nats");
    let report = train_lm(&model, train_ds, &cfg, |step, loss| {
        println!("step {step:>5}  loss {loss:.4}");
    })
    .expect("training failed");

    println!("\nloss curve (step, avg loss):");
    for (s, l) in &report.loss_curve {
        println!("  {s:>5}  {l:.4}");
    }
    println!("throughput: {:.1} sequences/s", report.throughput);

    // held-out evaluation
    let eval_ds = AutoregressiveLmDataset::new(corpus(2_000, 99), SEQ, SEQ);
    let mut eval_loss = 0.0;
    let n_eval = eval_ds.len().min(16);
    flashlight::autograd::no_grad(|| {
        for i in 0..n_eval {
            let w = flashlight::data::Dataset::get(&eval_ds, i);
            eval_loss +=
                flashlight::models::bert::lm_loss(&model, &w[0]).tensor().item() / n_eval as f64;
        }
    });
    println!("held-out loss: {eval_loss:.4} nats (uniform {uniform:.3})");

    // the model must beat the uniform baseline decisively
    assert!(
        report.final_loss < 0.6 * uniform,
        "LM failed to learn: {:.3} vs uniform {:.3}",
        report.final_loss,
        uniform
    );

    // greedy continuation follows the bigram rule most of the time
    let prompt: Vec<i64> = corpus(SEQ, 3).iter().map(|&t| t as i64).collect();
    let mut seq = prompt[..SEQ].to_vec();
    let mut rule_hits = 0;
    let total = 12;
    flashlight::autograd::no_grad(|| {
        for _ in 0..total {
            let ids =
                flashlight::tensor::Tensor::from_slice(&seq[seq.len() - SEQ..], [1, SEQ]);
            let logits = model.logits(&ids).tensor();
            let last = logits.narrow(1, SEQ - 1, 1);
            let next = last.argmax(-1, false).to_vec_i64()[0];
            let want = ((*seq.last().unwrap() as usize * 7 + 3) % VOCAB) as i64;
            rule_hits += i64::from(next == want);
            seq.push(next);
        }
    });
    println!("greedy continuation follows the bigram rule {rule_hits}/{total} steps");
    assert!(rule_hits as f64 >= total as f64 * 0.5, "sampling diverged from learned rule");
    println!("train_transformer OK");
}
