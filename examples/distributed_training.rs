//! Data-parallel training over the open `DistributedInterface`
//! (paper §4.1.3 / §A.4.1): 4 worker threads, each with a model replica,
//! parameters broadcast from rank 0, gradients averaged with the chunked
//! ring all-reduce after every step. Verifies replicas remain bitwise
//! synchronized and that the synchronized run matches a single-worker run
//! on the combined batch.
//!
//! Run: `cargo run --release --example distributed_training`

use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::dist::{init_ring, DistributedInterface, GradientSynchronizer};
use flashlight::models::mlp;
use flashlight::nn::{categorical_cross_entropy, Module};
use flashlight::optim::{Optimizer, SGDOptimizer};
use flashlight::tensor::{DType, Tensor};

const WORKERS: usize = 4;
const DIM: usize = 32;
const CLASSES: usize = 4;
const STEPS: usize = 10;

fn shard(rank: usize) -> (Tensor, Tensor) {
    // explicit per-rank generator: identical shards regardless of which
    // thread (worker vs sequential-replay) materializes them
    let mut rng = flashlight::util::rng::Rng::new(1000 + rank as u64);
    let xs: Vec<f32> = (0..8 * DIM).map(|_| rng.uniform_range(-1.0, 1.0) as f32).collect();
    let ys: Vec<i64> = (0..8).map(|_| rng.below(CLASSES) as i64).collect();
    (
        Tensor::from_slice(&xs, [8, DIM]),
        Tensor::from_slice(&ys, [8]).astype(DType::I64),
    )
}

fn main() {
    // ---- distributed run -------------------------------------------------
    let workers = init_ring(WORKERS);
    let mut handles = Vec::new();
    for w in workers {
        handles.push(std::thread::spawn(move || {
            let rank = w.world_rank();
            flashlight::util::rng::seed(42 + rank as u64); // divergent inits
            let model = mlp(&[DIM, 16, CLASSES]);
            let dist: Arc<dyn DistributedInterface + Sync> = Arc::new(w);
            // broadcast rank-0 parameters so replicas start identical
            for p in model.params() {
                p.set_tensor(dist.broadcast(&p.tensor(), 0));
            }
            let init_params: Vec<Vec<f32>> =
                model.params().iter().map(|p| p.tensor().to_vec()).collect();
            let sync = GradientSynchronizer::new(dist.clone());
            let mut opt = SGDOptimizer::new(model.params(), 0.1);
            let (x, y) = shard(rank);
            let mut losses = Vec::new();
            for _ in 0..STEPS {
                let out = model.forward(&Variable::constant(x.clone()));
                let loss = categorical_cross_entropy(&out, &y);
                losses.push(loss.tensor().item());
                loss.backward();
                sync.synchronize(&opt.params().to_vec());
                opt.step();
                opt.zero_grad();
            }
            let params: Vec<Vec<f32>> =
                model.params().iter().map(|p| p.tensor().to_vec()).collect();
            (rank, losses, params, init_params)
        }));
    }
    let mut results: Vec<(usize, Vec<f64>, Vec<Vec<f32>>, Vec<Vec<f32>>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();
    results.sort_by_key(|r| r.0);

    for (rank, losses, _, _) in &results {
        println!(
            "worker {rank}: loss {:.4} -> {:.4}",
            losses.first().unwrap(),
            losses.last().unwrap()
        );
    }

    // replicas must be exactly synchronized after training
    let reference = &results[0].2;
    for (rank, _, params, _) in &results[1..] {
        for (a, b) in reference.iter().zip(params) {
            let max_diff = a
                .iter()
                .zip(b)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 1e-5, "worker {rank} diverged by {max_diff}");
        }
    }
    println!("all {WORKERS} replicas bitwise-synchronized after {STEPS} steps");

    // ---- equivalence with single-worker training on the combined batch ---
    // replay from rank 0's exact broadcast initialization (thread-local
    // RNG stream assignment is racy across workers, so re-seeding alone
    // would not reproduce the same init)
    let model = mlp(&[DIM, 16, CLASSES]);
    for (p, init) in model.params().iter().zip(&results[0].3) {
        p.set_tensor(Tensor::from_slice(init, p.dims()));
    }
    let mut opt = SGDOptimizer::new(model.params(), 0.1);
    let shards: Vec<(Tensor, Tensor)> = (0..WORKERS).map(shard).collect();
    for _ in 0..STEPS {
        // average of per-shard gradients == gradient of the mean loss
        for p in model.params() {
            p.zero_grad();
        }
        for (x, y) in &shards {
            let out = model.forward(&Variable::constant(x.clone()));
            let loss = categorical_cross_entropy(&out, y);
            // scale each shard's loss by 1/WORKERS to mirror grad averaging
            flashlight::autograd::ops::mul_scalar(&loss, 1.0 / WORKERS as f64).backward();
        }
        opt.step();
    }
    let seq_params: Vec<Vec<f32>> = model.params().iter().map(|p| p.tensor().to_vec()).collect();
    let mut worst = 0.0f32;
    for (a, b) in reference.iter().zip(&seq_params) {
        for (x, y) in a.iter().zip(b) {
            worst = worst.max((x - y).abs());
        }
    }
    println!("distributed vs sequential parameter divergence: {worst:.2e}");
    assert!(worst < 1e-3, "ring training != sequential training ({worst})");
    println!("distributed_training OK");
}
