//! End-to-end serving demo: train a tiny LM on a synthetic bigram corpus,
//! checkpoint it (atomically), reload it into a fresh model, then serve it
//! — KV-cached greedy/top-k generation, dynamically-batched scoring, and
//! continuously-batched (iteration-level, paged-KV) generation through the
//! [`flashlight::serve::Engine`].
//!
//! Run: `cargo run --release --example generate_text [steps]`

use std::sync::Arc;
use std::time::Duration;

use flashlight::coordinator::{load_params, save_params, train_lm, TrainConfig};
use flashlight::models::BertLike;
use flashlight::nn::Module;
use flashlight::pkg::text::AutoregressiveLmDataset;
use flashlight::serve::{
    generate, ContinuousConfig, Engine, EngineConfig, GenerateOptions, Sampling,
};
use flashlight::tensor::Tensor;
use flashlight::util::rng::Rng;

const VOCAB: usize = 64;
const SEQ: usize = 16;

/// 90% of transitions follow `next = (prev * 5 + 1) % VOCAB`; the rest
/// are uniform noise, so a trained LM has an obvious greedy continuation.
fn corpus(len: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed);
    let mut toks = vec![1usize];
    for _ in 0..len {
        let prev = *toks.last().unwrap();
        let next =
            if rng.uniform() < 0.9 { (prev * 5 + 1) % VOCAB } else { rng.below(VOCAB) };
        toks.push(next);
    }
    toks
}

fn main() {
    let steps: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(150);
    flashlight::util::rng::seed(21);

    // ---- train ------------------------------------------------------------
    let train_ds = Arc::new(AutoregressiveLmDataset::new(corpus(12_000, 1), SEQ, 5));
    let model = BertLike::new(VOCAB, 64, 4, 2, 64);
    let cfg = TrainConfig {
        model: "bert".into(),
        optimizer: "adam".into(),
        lr: 1e-3,
        steps,
        batch_size: 16,
        grad_clip: 1.0,
        seed: 21,
        log_every: 50,
        ..Default::default()
    };
    let report = train_lm(&model, train_ds, &cfg, |step, loss| {
        println!("step {step:>4}  loss {loss:.4}");
    })
    .expect("training failed");
    println!("final loss {:.4} (uniform {:.3})\n", report.final_loss, (VOCAB as f64).ln());

    // ---- checkpoint round-trip (atomic save: tmp + rename) ----------------
    let ckpt = std::env::temp_dir().join("fl_generate_text.ckpt");
    save_params(&ckpt, &model.params()).expect("checkpoint save failed");
    let served = BertLike::new(VOCAB, 64, 4, 2, 64);
    load_params(&ckpt, &served.params()).expect("checkpoint load failed");
    let served = Arc::new(served);

    // ---- KV-cached generation --------------------------------------------
    let prompt: Vec<i64> = corpus(8, 9).iter().skip(1).map(|&t| t as i64).collect();
    let greedy = GenerateOptions {
        max_new_tokens: 24,
        sampling: Sampling::Greedy,
        seed: 0,
        use_cache: true,
        record_logits: false,
    };
    let cached = generate(&served, &prompt, &greedy).expect("generation failed");
    let recomputed = generate(
        &served,
        &prompt,
        &GenerateOptions { use_cache: false, ..greedy.clone() },
    )
    .expect("generation failed");
    assert_eq!(
        cached.tokens, recomputed.tokens,
        "KV-cached decode must match full recompute"
    );
    println!("prompt:    {prompt:?}");
    println!("greedy:    {:?}", &cached.tokens[prompt.len()..]);
    println!(
        "decode:    cached {:.1} tok/s vs recompute {:.1} tok/s ({:.2}x)",
        cached.tokens_per_sec,
        recomputed.tokens_per_sec,
        cached.tokens_per_sec / recomputed.tokens_per_sec.max(1e-9)
    );
    let creative = GenerateOptions {
        max_new_tokens: 24,
        sampling: Sampling::TopK { k: 4, temperature: 0.8 },
        seed: 1234,
        use_cache: true,
        record_logits: false,
    };
    let sampled = generate(&served, &prompt, &creative).expect("generation failed");
    println!("top-k:     {:?}", &sampled.tokens[prompt.len()..]);

    // how often the greedy continuation follows the planted bigram rule
    let gen = &cached.tokens[prompt.len()..];
    let mut prev = *prompt.last().unwrap() as usize;
    let mut hits = 0;
    for &t in gen {
        hits += usize::from(t as usize == (prev * 5 + 1) % VOCAB);
        prev = t as usize;
    }
    println!("bigram rule followed {hits}/{} steps\n", gen.len());

    // ---- dynamically-batched scoring through the engine -------------------
    let cfg = EngineConfig {
        max_batch_size: 8,
        max_wait: Duration::from_millis(2),
        workers: 2,
        decode: ContinuousConfig {
            max_active: 4,
            page_tokens: 8,
            pool_pages: None,
            ..Default::default()
        },
    };
    let engine = Engine::start_lm(Arc::clone(&served), SEQ, &[1, 8], &cfg)
        .expect("engine compile failed");
    let windows: Vec<Tensor> = (0..16)
        .map(|i| {
            let ids: Vec<i64> =
                corpus(SEQ, 100 + i).iter().skip(1).map(|&t| t as i64).collect();
            Tensor::from_slice(&ids, [SEQ])
        })
        .collect();
    let handles: Vec<_> = windows.iter().map(|w| engine.submit(w.copy())).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let logits = h.wait().expect("scoring failed");
        assert_eq!(logits.dims(), &[SEQ, VOCAB]);
        if i == 0 {
            let next = logits.narrow(0, SEQ - 1, 1).argmax(-1, false).to_vec_i64()[0];
            println!("window 0 greedy next token: {next}");
        }
    }
    let stats = engine.stats();
    println!(
        "engine: {} requests in {} batches (mean fill {:.2}), p50 {:.0}us p99 {:.0}us",
        stats.batcher.requests,
        stats.batcher.batches,
        stats.batcher.mean_batch_fill,
        stats.batcher.latency_p50_us,
        stats.batcher.latency_p99_us
    );

    // ---- continuously-batched generation ----------------------------------
    // four requests of different lengths share the iteration-level decode
    // batch over the paged KV pool; each report is bit-identical to a solo
    // generate() call with the same prompt, seed, and sampling
    let gen_handles: Vec<_> = (0..4u64)
        .map(|i| {
            let p: Vec<i64> =
                corpus(4 + i as usize * 2, 40 + i).iter().skip(1).map(|&t| t as i64).collect();
            let opts = GenerateOptions {
                max_new_tokens: 8 + 4 * i as usize,
                sampling: Sampling::TopK { k: 4, temperature: 0.9 },
                seed: i,
                ..Default::default()
            };
            (p.clone(), opts.clone(), engine.submit_generate(&p, &opts).unwrap())
        })
        .collect();
    for (i, (p, opts, h)) in gen_handles.into_iter().enumerate() {
        let rep = h.wait().expect("continuous generation failed");
        let solo = generate(&served, &p, &opts).expect("solo generation failed");
        assert_eq!(rep.tokens, solo.tokens, "continuous decode must match solo decode");
        println!("continuous {i}: {:?}", &rep.tokens[p.len()..]);
    }
    let stats = engine.stats();
    let decode = stats.decode.as_ref().expect("LM engines always have a decoder");
    println!(
        "decode pool: {} iterations (mean batch {:.2}), goodput {:.1} tok/s, \
         {} stalls, peak {} pages",
        decode.iterations,
        decode.mean_iteration_batch,
        stats.decode_tokens_per_sec,
        decode.backpressure_stalls,
        decode.pool.peak_leased_pages
    );
    engine.shutdown();
    println!("{} served. generate_text OK", Module::name(served.as_ref()));
}
