//! Speech-package pipeline (paper §4.3 "Speech"): featurize synthetic
//! waveforms -> train the ASR transformer with CTC -> decode with greedy
//! and LM-fused beam search, reporting token error rate with the
//! EditDistanceMeter.
//!
//! Run: `cargo run --release --example speech_pipeline`

use flashlight::autograd::{ops, Variable};
use flashlight::meter::EditDistanceMeter;
use flashlight::models::AsrTransformer;
use flashlight::nn::Module;
use flashlight::optim::{AdamOptimizer, Optimizer};
use flashlight::pkg::speech::{
    additive_noise, ctc_loss, greedy_decode, log_mel_spectrogram, BeamSearchDecoder, DecoderOpts,
    FeatureParams, NGramLm,
};
use flashlight::tensor::Tensor;
use flashlight::util::rng::Rng;

const TOKENS: usize = 5; // blank + 4 "phones"
const FRAMES: usize = 32;

/// Synthesize an utterance: each token is a tone segment; label = token seq.
fn utterance(labels: &[usize], rng: &mut Rng) -> Vec<f32> {
    let p = FeatureParams { frame_len: 256, hop: 128, n_mels: 16, sample_rate: 8000 };
    let samples_per_tok = (FRAMES / labels.len()) * p.hop;
    let mut wave = Vec::new();
    for &l in labels {
        let freq = 300.0 + 600.0 * l as f32;
        for i in 0..samples_per_tok {
            wave.push(0.5 * (2.0 * std::f32::consts::PI * freq * i as f32 / 8000.0).sin());
        }
    }
    additive_noise(&mut wave, 15.0, rng);
    wave
}

fn featurize(wave: &[f32]) -> Tensor {
    let p = FeatureParams { frame_len: 256, hop: 128, n_mels: 16, sample_rate: 8000 };
    let f = log_mel_spectrogram(wave, &p);
    let frames = f.dim(0).min(FRAMES);
    let f = f.narrow(0, 0, frames);
    // pad to FRAMES
    let f = f.pad(&[(0, FRAMES - frames), (0, 0)], 0.0);
    f.reshape(&[1, 1, FRAMES as isize, 16])
}

fn main() {
    flashlight::util::rng::seed(77);
    let mut rng = Rng::new(5);

    // training set: random 2-token sequences
    let seqs: Vec<Vec<usize>> =
        (0..12).map(|_| vec![1 + rng.below(TOKENS - 1), 1 + rng.below(TOKENS - 1)]).collect();
    let feats: Vec<Tensor> = seqs.iter().map(|s| featurize(&utterance(s, &mut rng))).collect();

    let model = AsrTransformer::new(16, 48, 4, 1, TOKENS);
    println!("acoustic model: {} params", flashlight::nn::num_params(&model));
    let mut opt = AdamOptimizer::new(model.params(), 3e-3);

    for epoch in 0..30 {
        let mut total = 0.0;
        for (f, s) in feats.iter().zip(&seqs) {
            let logits = model.forward(&Variable::constant(f.clone()));
            // [1, T', C] -> [T', C] log-probs
            let t = logits.dims()[1];
            let c = logits.dims()[2];
            let lp = ops::log_softmax(&ops::reshape(&logits, &[t as isize, c as isize]), -1);
            let loss = ctc_loss(&lp, s);
            total += loss.tensor().item();
            loss.backward();
            opt.step();
            opt.zero_grad();
        }
        if epoch % 5 == 0 {
            println!("epoch {epoch:>3}  ctc loss {:.3}", total / feats.len() as f64);
        }
    }

    // decode with greedy vs beam + LM
    let lm = NGramLm::train(TOKENS, &seqs, 0.2);
    let beam = BeamSearchDecoder::new(
        DecoderOpts { beam: 8, lm_weight: 0.4, word_bonus: 0.0 },
        Some(lm),
    );
    let mut greedy_ter = EditDistanceMeter::new();
    let mut beam_ter = EditDistanceMeter::new();
    flashlight::autograd::no_grad(|| {
        for (f, s) in feats.iter().zip(&seqs) {
            let logits = model.forward(&Variable::constant(f.clone()));
            let t = logits.dims()[1];
            let c = logits.dims()[2];
            let lp = logits.tensor().reshape(&[t as isize, c as isize]).log_softmax(-1);
            greedy_ter.add(&greedy_decode(&lp), s);
            beam_ter.add(&beam.decode(&lp), s);
        }
    });
    println!("token error rate: greedy {:.1}%  beam+LM {:.1}%", greedy_ter.value(), beam_ter.value());
    assert!(greedy_ter.value() < 60.0, "acoustic model failed to learn");
    assert!(beam_ter.value() <= greedy_ter.value() + 1e-9, "beam+LM should not be worse");
    println!("speech_pipeline OK");
}
