//! Quickstart: the paper's Appendix A.4.3 MNIST walkthrough, end to end —
//! `BatchDataset` over held-out splits (Listing 7), the exact `Sequential`
//! CNN of Listing 8, the training loop of Listing 9, and the eval loop of
//! Listing 10 — on a synthetic MNIST-like dataset (no network access on
//! this testbed).
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::data::{BatchDataset, Dataset, TensorDataset};
use flashlight::meter::{AverageValueMeter, FrameErrorMeter};
use flashlight::nn::conv::Padding;
use flashlight::nn::{
    categorical_cross_entropy, Conv2D, Dropout, Linear, LogSoftmax, Module, Pool2D, ReLU,
    Sequential, View,
};
use flashlight::optim::{Optimizer, SGDOptimizer};
use flashlight::tensor::{index::range, index::span, DType, Tensor};
use flashlight::util::rng::Rng;

const K_IMAGE_DIM: usize = 16; // scaled from 28 for CPU speed
const K_CLASSES: usize = 10;

/// Synthetic MNIST stand-in: each class is a distinct stroke pattern plus
/// noise (separable but non-trivial).
fn load_dataset(n: usize, seed: u64) -> (Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let d = K_IMAGE_DIM;
    let mut xs = Vec::with_capacity(n * d * d);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let k = rng.below(K_CLASSES);
        ys.push(k as i64);
        for p in 0..d * d {
            let (y, x) = (p / d, p % d);
            // class-specific diagonal stripe pattern
            let stripe = ((x + k * y) % K_CLASSES == k) as u8 as f32;
            xs.push(stripe + 0.25 * rng.normal() as f32);
        }
    }
    (
        Tensor::from_slice(&xs, [n, d * d]),
        Tensor::from_slice(&ys, [n]).astype(DType::I64),
    )
}

fn eval_loop(model: &Sequential, dataset: &BatchDataset) -> (f64, f64) {
    let mut loss_meter = AverageValueMeter::new();
    let mut error_meter = FrameErrorMeter::new();
    flashlight::autograd::no_grad(|| {
        for i in 0..dataset.len() {
            let example = dataset.get(i);
            let inputs = Variable::constant(example[0].clone());
            let output = model.forward(&inputs);
            let max_ids = output.tensor().argmax(-1, false);
            error_meter.add(&max_ids, &example[1]);
            let loss = categorical_cross_entropy(&output, &example[1]);
            loss_meter.add(loss.tensor().item());
        }
    });
    (loss_meter.value(), error_meter.value())
}

fn main() {
    flashlight::util::rng::seed(1234);
    const K_TRAIN_SIZE: usize = 600;
    const K_VAL_SIZE: usize = 100;
    let batch_size = 32;
    let epochs = 6;
    let learning_rate = 0.05;

    let (train_x, train_y) = load_dataset(K_TRAIN_SIZE, 1);
    // Hold out a dev set (paper Listing 7's span/range indexing)
    let val_x = train_x.index(&[range(0, K_VAL_SIZE), span()]);
    let tr_x = train_x.index(&[range(K_VAL_SIZE, K_TRAIN_SIZE), span()]);
    let val_y = val_y_slice(&train_y, 0, K_VAL_SIZE);
    let tr_y = val_y_slice(&train_y, K_VAL_SIZE, K_TRAIN_SIZE);

    let trainset = BatchDataset::new(
        Arc::new(TensorDataset::new(vec![tr_x, tr_y])),
        batch_size,
    );
    let valset = BatchDataset::new(
        Arc::new(TensorDataset::new(vec![val_x, val_y])),
        batch_size,
    );

    // Listing 8's Sequential CNN (scaled kernel plan for 16x16)
    let pad = Padding::Same;
    let mut model = Sequential::new();
    model.add(View::new(&[-1, 1, K_IMAGE_DIM as isize, K_IMAGE_DIM as isize]));
    model.add(Conv2D::new(1, 16, 5, 5, 1, 1, pad, pad));
    model.add(ReLU);
    model.add(Pool2D::max(2, 2, 2, 2));
    model.add(Conv2D::new(16, 32, 5, 5, 1, 1, pad, pad));
    model.add(ReLU);
    model.add(Pool2D::max(2, 2, 2, 2));
    model.add(View::new(&[-1, (K_IMAGE_DIM / 4 * K_IMAGE_DIM / 4 * 32) as isize]));
    model.add(Linear::new(K_IMAGE_DIM / 4 * K_IMAGE_DIM / 4 * 32, 128));
    model.add(ReLU);
    model.add(Dropout::new(0.5));
    model.add(Linear::new(128, K_CLASSES));
    model.add(LogSoftmax);
    println!("model: {} ({} params)", model.name(), flashlight::nn::num_params(&model));

    // Listing 9's training loop
    let mut opt = SGDOptimizer::new(model.params(), learning_rate);
    for e in 0..epochs {
        let mut train_loss_meter = AverageValueMeter::new();
        for i in 0..trainset.len() {
            let example = trainset.get(i);
            let inputs = Variable::constant(example[0].clone());
            let output = model.forward(&inputs);
            let loss = categorical_cross_entropy(&output, &example[1]);
            train_loss_meter.add(loss.tensor().item());
            loss.backward();
            opt.step();
            opt.zero_grad();
        }
        let (val_loss, val_error) = eval_loop(&model, &valset);
        println!(
            "Epoch {e}: Avg Train Loss: {:.3} Validation Loss: {:.3} Validation Error (%): {:.1}",
            train_loss_meter.value(),
            val_loss,
            val_error
        );
    }
    let (_, final_err) = eval_loop(&model, &valset);
    assert!(final_err < 20.0, "quickstart failed to learn: {final_err}%");
    println!("quickstart OK (val error {final_err:.1}%)");
}

fn val_y_slice(y: &Tensor, start: usize, end: usize) -> Tensor {
    y.narrow(0, start, end - start)
}
