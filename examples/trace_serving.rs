//! Observability demo: serve a small LM with tracing on, then export the
//! whole capture — compiler passes, per-instruction execution samples,
//! prefill chunks, decode iterations, allocator events, and per-request
//! timelines — as Chrome trace-event JSON (`trace.json`), plus a metrics
//! dump from the process-wide registry.
//!
//! Run: `cargo run --release --example trace_serving`
//! then open `trace.json` in Perfetto (https://ui.perfetto.dev) or
//! `chrome://tracing`.

use std::sync::Arc;
use std::time::Duration;

use flashlight::memory::{self, DefaultMemoryManager, TelemetryMemoryManager};
use flashlight::models::BertLike;
use flashlight::obs;
use flashlight::serve::{ContinuousConfig, Engine, EngineConfig, GenerateOptions, Sampling};
use flashlight::tensor::Tensor;

const VOCAB: usize = 64;
const SEQ: usize = 16;

fn main() {
    flashlight::util::rng::seed(7);

    // everything below records; FL_TRACE=1 would do the same without code
    obs::set_enabled(true);
    // time individual compiled-program instructions on every 4th run
    // (default: every 16th) — visible as nested spans under "exec.run"
    obs::set_exec_sample_every(4);
    // bridge allocator traffic onto the same timeline as "mem.alloc" /
    // "mem.free" instants
    let telemetry = Arc::new(TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new())));
    let prev_mgr = memory::install(telemetry.clone());

    // deploy a small LM: the bucket compiles (spans "compile",
    // "serve.session.compile_bucket", "serve.decode.compile_bucket") all
    // land in the trace because recording is already on
    let model = Arc::new(BertLike::new(VOCAB, 64, 4, 2, 64));
    let cfg = EngineConfig {
        max_batch_size: 4,
        max_wait: Duration::from_millis(2),
        workers: 2,
        decode: ContinuousConfig {
            max_active: 4,
            page_tokens: 8,
            pool_pages: None,
            // a long prompt below splits into 6-token prefill chunks
            prefill_chunk: Some(6),
            ..Default::default()
        },
    };
    let engine =
        Engine::start_lm(Arc::clone(&model), SEQ, &[1, 4], &cfg).expect("engine compile failed");

    // scoring traffic through the dynamic batcher ("serve.batch" spans +
    // collector-published request timelines)
    let score_handles: Vec<_> = (0..6)
        .map(|i| {
            let ids: Vec<i64> = (0..SEQ).map(|j| ((i * 13 + j * 5) % VOCAB) as i64).collect();
            engine.submit(Tensor::from_slice(&ids, [SEQ]))
        })
        .collect();
    for h in score_handles {
        h.wait().expect("scoring failed");
    }

    // generation traffic through the continuous scheduler: overlapping
    // requests of different lengths, so the trace shows prefill chunks
    // interleaved with multi-row decode iterations
    let gen_handles: Vec<_> = (0..4u64)
        .map(|i| {
            let prompt: Vec<i64> =
                (0..4 + 3 * i as usize).map(|j| ((j * 11 + i as usize) % VOCAB) as i64).collect();
            let opts = GenerateOptions {
                max_new_tokens: 6 + 2 * i as usize,
                sampling: Sampling::TopK { k: 4, temperature: 0.9 },
                seed: i,
                ..Default::default()
            };
            engine.submit_generate(&prompt, &opts).expect("submit failed")
        })
        .collect();
    for (i, h) in gen_handles.into_iter().enumerate() {
        let report = h.wait().expect("generation failed");
        let tl = report.timeline.as_ref().expect("tracing is on: every report has a timeline");
        let samples = tl.events.iter().filter(|e| e.what == "sample").count();
        let compiled = tl.events.iter().filter(|e| e.what == "sample" && e.compiled).count();
        let chunks = tl.events.iter().filter(|e| e.what == "prefill_chunk").count();
        println!(
            "request {i}: {} tokens in {samples} samples ({compiled} compiled-iteration), \
             {chunks} prefill chunk(s)",
            report.generated
        );
        assert_eq!(samples, report.generated, "timeline ledger");
    }
    let stats = engine.stats(); // publishes serve.* into the registry
    let decode = stats.decode.as_ref().expect("LM engines always have a decoder");
    println!(
        "served {} scoring requests, {} generations ({} decode iterations)\n",
        stats.batcher.requests, decode.completed, decode.iterations
    );
    engine.shutdown();

    // one file, every layer: open it in Perfetto and the compile spans,
    // executor samples, serve iterations, allocator instants, and async
    // per-request timelines sit on one coherent clock
    obs::export_chrome_trace("trace.json").expect("trace export failed");
    println!("wrote trace.json ({} spans dropped by ring overflow)", obs::dropped_spans());
    println!("\nmetrics registry:\n{}", obs::metrics_text());

    memory::install(prev_mgr);
}
