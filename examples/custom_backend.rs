//! Paper §5.2.4 reproduced as a runnable artifact: swap the **source of
//! truth for `add`** behind the single dispatch choke point and watch
//! every derived operator, model, and baseline in the framework pick it
//! up with zero call-site changes — then do the same with the deferred
//! (lazy) and AOT (XLA) backends to demonstrate Figure 2's
//! computation-mode freedom, and finish with the two IR-powered tools
//! (profiling, trace capture + replay) that each take *one function* to
//! build.
//!
//! Run: `cargo run --release --example custom_backend`

use std::sync::atomic::{AtomicU64, Ordering};

use flashlight::autograd::Variable;
use flashlight::models::mlp;
use flashlight::nn::Module;
use flashlight::tensor::cpu::CpuBackend;
use flashlight::tensor::lazy::{pending_ops, LazyBackend};
use flashlight::tensor::{
    BackendGuard, InterposedBackend, Interposer, Op, ProfilingBackend, Tensor, TensorBackend,
    TraceBackend,
};
use flashlight::util::error::Result;

/// A research backend that replaces `add`: one intercept function instead
/// of a 60-method delegation surface. A real project would plug in its
/// novel element-wise implementation where the counter bumps.
struct CustomAdd {
    adds: AtomicU64,
}

impl Interposer for CustomAdd {
    fn name(&self) -> &str {
        "custom-add"
    }
    fn intercept(&self, op: &Op, inputs: &[&Tensor], inner: &dyn TensorBackend) -> Result<Tensor> {
        if matches!(op, Op::Add) {
            self.adds.fetch_add(1, Ordering::Relaxed);
            // ... novel element-wise implementation goes here ...
        }
        inner.dispatch(op, inputs)
    }
}

fn main() {
    // 1) swap the default backend — one line, whole framework retargets
    let be = InterposedBackend::over_cpu(CustomAdd { adds: AtomicU64::new(0) });
    {
        let _guard = BackendGuard::install(be.clone());
        // an existing model, untouched: every add (bias adds, residuals,
        // gelu composition, autograd accumulation) hits the custom op
        let model = mlp(&[32, 64, 64, 10]);
        let x = Variable::constant(Tensor::rand([8, 32], -1.0, 1.0));
        let y = model.forward(&x);
        flashlight::autograd::ops::sum(&y, &[], false).backward();
        let n = be.interposer().adds.load(Ordering::Relaxed);
        println!("custom `add` dispatched {n} times through an unmodified MLP fwd+bwd");
        // 3 bias adds forward + gradient accumulation on the backward pass
        assert!(n >= 3, "custom add was bypassed (n={n})");
    }

    // 2) same model on the deferred backend: ops queue until materialized
    {
        let _guard = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::rand([64, 64], -1.0, 1.0);
        let expr = a.add(&a).tanh().mul(&a).sub(&a).exp();
        println!("lazy backend: {} ops pending before materialization", pending_ops(&expr));
        assert!(pending_ops(&expr) >= 5);
        let v = expr.to_vec(); // forces fused evaluation
        println!("materialized {} values in one fused pass", v.len());
    }

    // 3) and on the AOT/XLA backend: hot matmuls run as PJRT executables
    match flashlight::tensor::xla_backend::XlaBackend::from_global_runtime() {
        Some(xla) => {
            let _guard = BackendGuard::install(xla.clone());
            let x = Tensor::rand([32, 256], -1.0, 1.0);
            let w = Tensor::rand([256, 256], -1.0, 1.0);
            let _ = x.matmul(&w);
            let (off, fall) = xla.counts();
            println!("xla-aot backend: {off} ops offloaded to PJRT, {fall} fell back");
            assert!(off >= 1);
        }
        None => println!("(artifacts/ not built — skipping the AOT backend demo)"),
    }

    // 4) per-op profiling: a cross-cutting concern that used to need ~60
    //    overrides, now shipped as one intercept function
    {
        let prof = ProfilingBackend::over_cpu_default();
        let _guard = BackendGuard::install(prof.clone());
        let a = Tensor::rand([32, 32], -1.0, 1.0);
        let _ = a.matmul(&a).gelu().softmax(-1).to_vec();
        let stats = prof.interposer().snapshot();
        println!("profiler saw {} distinct op kinds; top 3 by time:", stats.len());
        for s in stats.iter().take(3) {
            println!("  {:<12} {:>5} calls  {:>9.1} µs total", s.op, s.calls, s.total_ns / 1e3);
        }
        assert!(prof.interposer().total_calls() > 0);
    }

    // 5) trace capture: run the program once, get a portable Vec<Op>
    //    program, replay it bit-identically on the plain CPU backend
    {
        let tracer = TraceBackend::over_cpu_default();
        let traced = {
            let _guard = BackendGuard::install(tracer.clone());
            let a = Tensor::from_slice(&(0..64).map(|i| i as f32 * 0.1).collect::<Vec<_>>(), [8, 8]);
            a.matmul(&a).add(&a).tanh().sum(&[-1], false).to_vec()
        };
        let program = tracer.interposer().program();
        println!("captured a {}-op program: {:?}", program.len(), program.op_names());
        let replayed =
            program.replay_on(CpuBackend::shared().as_ref()).expect("replay failed");
        assert_eq!(
            traced,
            replayed.last().unwrap().to_vec(),
            "replay must be bit-identical to the traced run"
        );
        println!("replayed on the plain CPU backend: bit-identical");
    }

    // 6) compiled execution: trace a function once, optimize the capture
    //    (DCE / constant folding / CSE / element-wise fusion + a liveness
    //    memory plan), then call it like a function with fresh inputs
    {
        use flashlight::tensor::graph::trace_and_compile;
        let ex = [Tensor::rand([64, 64], -1.0, 1.0), Tensor::rand([64, 64], 0.1, 2.0)];
        let cf = trace_and_compile(&ex, |args| {
            let wasted = args[0].mul(&args[1]); // dead: eliminated by DCE
            let _ = wasted;
            let e = args[0].add(&args[1]).tanh(); // shared by both branches
            e.mul(&e).sub(&args[1]) // diamond: fuses into one kernel
        })
        .expect("trace_and_compile failed");
        println!(
            "compiled fn: {} instr(s) [{}], pipeline {{{}}}",
            cf.program().len(),
            cf.program().op_names().join(", "),
            cf.program().report.summary()
        );
        // fresh arguments, same shapes: parameters are substituted, the
        // result matches eager execution
        let (x, y) = (Tensor::rand([64, 64], -1.0, 1.0), Tensor::rand([64, 64], 0.1, 2.0));
        let compiled_out = cf.call(CpuBackend::shared().as_ref(), &[&x, &y]).unwrap();
        let e = x.add(&y).tanh();
        let eager_out = e.mul(&e).sub(&y);
        assert_eq!(
            compiled_out.to_vec(),
            eager_out.to_vec(),
            "compiled execution must be bit-identical to eager"
        );
        println!("compiled call matches eager execution bit-for-bit");
    }

    println!("custom_backend OK — three computation modes + three IR tools behind one choke point");
}
