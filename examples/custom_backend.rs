//! Paper §5.2.4 reproduced as a runnable artifact: swap the **source of
//! truth for `add`** behind the small backend API and watch every derived
//! operator, model, and baseline in the framework pick it up with zero
//! call-site changes — then do the same with the deferred (lazy) and
//! AOT (XLA) backends to demonstrate Figure 2's computation-mode freedom.
//!
//! Run: `cargo run --release --example custom_backend`

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::models::mlp;
use flashlight::nn::Module;
use flashlight::tensor::cpu::CpuBackend;
use flashlight::tensor::delegate::DelegateBackend;
use flashlight::tensor::lazy::{pending_ops, LazyBackend};
use flashlight::tensor::{BackendGuard, Tensor, TensorBackend};

/// A research backend that replaces `add` (here: counting + delegating;
/// a real project would plug in its novel element-wise implementation).
struct CustomAdd {
    inner: Arc<dyn TensorBackend>,
    adds: AtomicU64,
}

impl DelegateBackend for CustomAdd {
    fn inner(&self) -> Arc<dyn TensorBackend> {
        self.inner.clone()
    }
    fn wrapper_name(&self) -> &str {
        "custom-add"
    }
    fn add(&self, a: &Tensor, b: &Tensor) -> Tensor {
        self.adds.fetch_add(1, Ordering::Relaxed);
        // ... novel element-wise implementation goes here ...
        self.inner.add(a, b)
    }
}

flashlight::impl_delegate_backend!(CustomAdd);

fn main() {
    // 1) swap the default backend — one line, whole framework retargets
    let be = Arc::new(CustomAdd { inner: CpuBackend::shared(), adds: AtomicU64::new(0) });
    {
        let _guard = BackendGuard::install(be.clone());
        // an existing model, untouched: every add (bias adds, residuals,
        // gelu composition, autograd accumulation) hits the custom op
        let model = mlp(&[32, 64, 64, 10]);
        let x = Variable::constant(Tensor::rand([8, 32], -1.0, 1.0));
        let y = model.forward(&x);
        flashlight::autograd::ops::sum(&y, &[], false).backward();
        let n = be.adds.load(Ordering::Relaxed);
        println!("custom `add` dispatched {n} times through an unmodified MLP fwd+bwd");
        // 3 bias adds forward + gradient accumulation on the backward pass
        assert!(n >= 3, "custom add was bypassed (n={n})");
    }

    // 2) same model on the deferred backend: ops queue until materialized
    {
        let _guard = BackendGuard::install(LazyBackend::shared());
        let a = Tensor::rand([64, 64], -1.0, 1.0);
        let expr = a.add(&a).tanh().mul(&a).sub(&a).exp();
        println!("lazy backend: {} ops pending before materialization", pending_ops(&expr));
        assert!(pending_ops(&expr) >= 5);
        let v = expr.to_vec(); // forces fused evaluation
        println!("materialized {} values in one fused pass", v.len());
    }

    // 3) and on the AOT/XLA backend: hot matmuls run as PJRT executables
    match flashlight::tensor::xla_backend::XlaBackend::from_global_runtime() {
        Some(xla) => {
            let _guard = BackendGuard::install(xla.clone());
            let x = Tensor::rand([32, 256], -1.0, 1.0);
            let w = Tensor::rand([256, 256], -1.0, 1.0);
            let _ = x.matmul(&w);
            let (off, fall) = xla.counts();
            println!("xla-aot backend: {off} ops offloaded to PJRT, {fall} fell back");
            assert!(off >= 1);
        }
        None => println!("(artifacts/ not built — skipping the AOT backend demo)"),
    }

    println!("custom_backend OK — three computation modes behind one API");
}
