//! Paper §5.2.3: "tensors can follow any preordained allocation schedule
//! or rules ... sharded or computations dispatched to arbitrary devices".
//!
//! This demo builds a ZeRO-style optimizer-state sharding schedule over the
//! open memory + distributed interfaces: each of 4 workers *owns* 1/4 of
//! the parameters' optimizer state, updates its shard locally, and
//! broadcasts the refreshed parameters — cutting per-worker optimizer-state
//! memory 4× while producing updates identical to the unsharded run.
//!
//! Run: `cargo run --release --example offload_schedule`

use flashlight::dist::{init_ring, DistributedInterface};
use flashlight::tensor::Tensor;

const WORLD: usize = 4;
const N_PARAMS: usize = 8;
const DIM: usize = 64;

fn main() {
    flashlight::util::rng::seed(31);
    // shared "model": N parameter tensors + fixed per-step gradients
    let init: Vec<Vec<f32>> = (0..N_PARAMS).map(|_| Tensor::rand([DIM], -1.0, 1.0).to_vec()).collect();
    let grads: Vec<Vec<f32>> = (0..N_PARAMS).map(|_| Tensor::rand([DIM], -0.1, 0.1).to_vec()).collect();

    // ---- unsharded reference: every worker keeps full momentum state ----
    let lr = 0.1f32;
    let beta = 0.9f32;
    let mut ref_params = init.clone();
    let mut momentum = vec![vec![0.0f32; DIM]; N_PARAMS];
    for _step in 0..5 {
        for p in 0..N_PARAMS {
            for i in 0..DIM {
                momentum[p][i] = beta * momentum[p][i] + grads[p][i];
                ref_params[p][i] -= lr * momentum[p][i];
            }
        }
    }

    // ---- ZeRO-style sharded run over the distributed interface ----------
    let workers = init_ring(WORLD);
    let mut handles = Vec::new();
    for w in workers {
        let init = init.clone();
        let grads = grads.clone();
        handles.push(std::thread::spawn(move || {
            let rank = w.world_rank();
            let mut params = init;
            // preordained schedule: worker r owns optimizer state for
            // params p with p % WORLD == r (the paper's "any preordained
            // allocation schedule")
            let owned: Vec<usize> = (0..N_PARAMS).filter(|p| p % WORLD == rank).collect();
            let mut my_momentum: Vec<Vec<f32>> = owned.iter().map(|_| vec![0.0; DIM]).collect();
            let state_bytes = my_momentum.len() * DIM * 4;
            for _step in 0..5 {
                // each worker updates only its owned shard...
                for (slot, &p) in owned.iter().enumerate() {
                    for i in 0..DIM {
                        my_momentum[slot][i] = 0.9 * my_momentum[slot][i] + grads[p][i];
                        params[p][i] -= 0.1 * my_momentum[slot][i];
                    }
                }
                // ...then every param is broadcast from its owner
                for p in 0..N_PARAMS {
                    let owner = p % WORLD;
                    let t = Tensor::from_slice(&params[p], [DIM]);
                    params[p] = w.broadcast(&t, owner).to_vec();
                }
            }
            (rank, state_bytes, params)
        }));
    }
    let results: Vec<(usize, usize, Vec<Vec<f32>>)> =
        handles.into_iter().map(|h| h.join().unwrap()).collect();

    let full_state = N_PARAMS * DIM * 4;
    for (rank, bytes, params) in &results {
        let mut worst = 0.0f32;
        for (a, b) in params.iter().zip(&ref_params) {
            for (x, y) in a.iter().zip(b) {
                worst = worst.max((x - y).abs());
            }
        }
        println!(
            "worker {rank}: optimizer state {bytes} B ({}x reduction), divergence {worst:.2e}",
            full_state / bytes
        );
        assert!(worst < 1e-5, "sharded update diverged");
    }
    println!("offload_schedule OK — sharded schedule matches unsharded updates exactly");
}
