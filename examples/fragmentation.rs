//! Paper §5.2.2 reproduced: install a telemetry-wrapped caching memory
//! manager, run real model training to capture an op-attributed allocation
//! trace, then replay the identical trace through the unrestricted vs
//! split-restricted caching managers and report the fragmentation delta
//! (the paper's researchers saw >20% internal-fragmentation reduction).
//!
//! Run: `cargo run --release --example fragmentation`

use std::sync::Arc;

use flashlight::autograd::Variable;
use flashlight::memory::{
    self, CachingMemoryManager, DefaultMemoryManager, TelemetryMemoryManager,
};
use flashlight::models::{alexnet, BertLike};
use flashlight::nn::{categorical_cross_entropy, Module};
use flashlight::optim::{AdamOptimizer, Optimizer};
use flashlight::tensor::{DType, Tensor};

fn capture_trace(label: &str, steps: usize, mut run_step: impl FnMut()) -> Vec<memory::AllocEvent> {
    let telemetry = Arc::new(TelemetryMemoryManager::new(Arc::new(DefaultMemoryManager::new())));
    let prev = memory::install(telemetry.clone());
    for _ in 0..steps {
        run_step();
    }
    if let Some(p) = prev {
        memory::install(p);
    }
    let trace = telemetry.trace();
    println!("{label}: captured {} allocator events", trace.len());
    println!("  top ops by allocated bytes:");
    for (op, n, bytes) in telemetry.by_op().into_iter().take(5) {
        println!("    {op:<16} {n:>6} allocs  {:>10.1} KiB", bytes as f64 / 1024.0);
    }
    trace
}

fn replay_and_report(label: &str, trace: &[memory::AllocEvent]) -> (f64, f64) {
    let unrestricted = CachingMemoryManager::unrestricted();
    let (_, frag_u) = memory::telemetry::replay(trace, &unrestricted);
    let restricted = CachingMemoryManager::split_restricted(4 << 20); // 4 MiB
    let (_, frag_r) = memory::telemetry::replay(trace, &restricted);
    let delta = (frag_u - frag_r) / frag_u.max(1e-9) * 100.0;
    println!(
        "{label}: peak fragmentation {:.1}% (unrestricted) -> {:.1}% (split<=4MiB), reduction {delta:.0}%",
        frag_u * 100.0,
        frag_r * 100.0
    );
    (frag_u, frag_r)
}

/// Large-activation churn trace (GPU-scale buffer sizes — the regime the
/// paper's case study targets; our CPU-scaled models only allocate a few
/// MB, which stay in the small pool where splitting is always safe).
fn large_activation_trace(steps: usize) -> Vec<memory::AllocEvent> {
    use flashlight::util::rng::Rng;
    let mut rng = Rng::new(42);
    let (mut events, mut id) = (Vec::new(), 0u64);
    let mut retained: Vec<u64> = Vec::new();
    for _ in 0..steps {
        let mut step_ids = Vec::new();
        for _ in 0..6 {
            let mb = 8 + rng.below(56);
            events.push(memory::AllocEvent {
                kind: memory::EventKind::Alloc,
                bytes: mb << 20,
                id,
                op: "activation",
            });
            step_ids.push(id);
            id += 1;
        }
        let keep = step_ids[rng.below(step_ids.len())];
        for s in step_ids {
            if s != keep {
                events.push(memory::AllocEvent {
                    kind: memory::EventKind::Free,
                    bytes: 0,
                    id: s,
                    op: "activation",
                });
            } else {
                retained.push(s);
            }
        }
        if retained.len() > 3 {
            let victim = retained.remove(0);
            events.push(memory::AllocEvent {
                kind: memory::EventKind::Free,
                bytes: 0,
                id: victim,
                op: "activation",
            });
        }
    }
    events
}

fn main() {
    flashlight::util::rng::seed(11);

    // 1) transformer training trace
    let bert = BertLike::new(200, 64, 4, 1, 17);
    let ids = Tensor::rand([4, 17], 0.0, 200.0).astype(DType::I64);
    let mut opt = AdamOptimizer::new(bert.params(), 1e-3);
    let t1 = capture_trace("bert-like training", 3, || {
        let loss = flashlight::models::bert::lm_loss(&bert, &ids);
        loss.backward();
        opt.step();
        opt.zero_grad();
    });

    // 2) CNN training trace
    let cnn = alexnet(10);
    let x = Tensor::rand([4, 3, 32, 32], -1.0, 1.0);
    let y = Tensor::rand([4], 0.0, 10.0).astype(DType::I64);
    let mut copt = AdamOptimizer::new(cnn.params(), 1e-3);
    let t2 = capture_trace("alexnet training", 2, || {
        let out = cnn.forward(&Variable::constant(x.clone()));
        let loss = categorical_cross_entropy(&out, &y);
        loss.backward();
        copt.step();
        copt.zero_grad();
    });

    println!();
    let (u1, r1) = replay_and_report("bert-like", &t1);
    let (u2, r2) = replay_and_report("alexnet ", &t2);
    let t3 = large_activation_trace(40);
    let (u3, r3) = replay_and_report("large-activation churn", &t3);
    assert!(r1 <= u1 + 1e-9 && r2 <= u2 + 1e-9 && r3 <= u3 + 1e-9);

    let reduction = (u3 - r3) / u3.max(1e-9) * 100.0;
    println!(
        "\nlarge-pool fragmentation reduction: {reduction:.0}% (paper reports >20%; \
         the scaled models' traces live in the always-splittable small pool)"
    );
    println!("fragmentation OK");
}
